"""Dynamic membership across engine and service layers.

Engine contract: after any in-capacity membership delta,
``repair_sharded_topo`` must equal a full ``shard_topology`` rebuild
bitwise, and an engine whose tables were repaired mid-run must stay
cycle-for-cycle identical to the core loop on the same mutated topology.
Service contract: joins/leaves/rewires land at dispatch boundaries with
zero recompiles, joining peers start from the paper's knowledge-init
state, and a tenant's stream of telemetry is exactly what a hand-rolled
single-query loop produces under the same membership schedule.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lss, regions, sim, topology, wvs
from repro.obs import jit_cache_size
from repro.engine import (EngineConfig, ShardedLSS, make_partition,
                          repair_sharded_topo, shard_topology)
from repro.service import QuerySpec, Service, ServiceConfig

DynTopology = topology.DynTopology


def _mutate(dyn, rng, ops):
    for _ in range(ops):
        op = rng.integers(4)
        try:
            if op == 0:
                dyn.add_peer()
            elif op == 1:
                dyn.remove_peer(int(rng.choice(np.flatnonzero(dyn.present))))
            elif op == 2:
                cand = np.flatnonzero(dyn.present)
                i, j = rng.choice(cand, size=2, replace=False)
                dyn.add_edge(int(i), int(j))
            else:
                edges = dyn.edge_list()
                if edges:
                    dyn.remove_edge(*edges[rng.integers(len(edges))])
        except ValueError:
            pass


# ---------------------------------------------------------------------------
# incremental halo repair == full repartition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shards", [2, 4])
def test_incremental_repair_equals_full_rebuild(shards):
    rng = np.random.default_rng(11)
    dyn = DynTopology.from_topology(topology.grid(49), n_cap=56, deg_cap=6,
                                    strict=True)
    part = make_partition(dyn, shards)
    st = shard_topology(dyn, part)
    ver = dyn.version
    for step in range(60):
        _mutate(dyn, rng, 1)
        st = repair_sharded_topo(st, dyn, dyn.changed_rows_since(ver))
        ver = dyn.version
        full = shard_topology(dyn, part, halo_width=st.halo_width)
        for name in ("mask", "rev", "tgt_shard", "tgt_row", "tgt_pos",
                     "intra"):
            assert np.array_equal(getattr(st, name), getattr(full, name)), \
                (step, name)
        for a, b in zip(st.halo, full.halo):
            assert np.array_equal(a, b), step
        assert st.num_edges == full.num_edges == dyn.num_edges


def test_repair_regrows_halo_width_on_overflow():
    """Cross-shard edge churn past the halo headroom regrows the tables
    (shape change) and still matches the full rebuild exactly."""
    dyn = DynTopology.from_topology(topology.grid(16), deg_cap=6,
                                    strict=True)
    part = make_partition(dyn, 2, method="stride")
    st = shard_topology(dyn, part)
    H0 = st.halo_width
    ver = dyn.version
    # Stride splits rows 0..7 | 8..15; every new (low, high) pair is a
    # fresh cut edge, quickly overflowing the tight initial width.
    added = 0
    for i in range(8):
        for j in range(8, 16):
            if not dyn.has_edge(i, j) and dyn.degrees[i] < 6 \
                    and dyn.degrees[j] < 6:
                dyn.add_edge(i, j)
                added += 1
    assert added > 0
    st = repair_sharded_topo(st, dyn, dyn.changed_rows_since(ver))
    assert st.halo_width > H0  # regrown
    full = shard_topology(dyn, part, halo_width=st.halo_width)
    for a, b in zip(st.halo, full.halo):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# engine: membership mid-run, cycle-for-cycle vs core
# ---------------------------------------------------------------------------


def test_engine_membership_midrun_matches_core():
    dyn = DynTopology.from_topology(topology.grid(36), n_cap=40, deg_cap=6,
                                    strict=True)
    centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=40, seed=2))
    x = sample(np.random.default_rng(3), 40)
    inputs = wvs.from_vector(jnp.asarray(x), jnp.ones((40,), jnp.float32))
    cfg = lss.LSSConfig()

    eng = ShardedLSS(dyn, centers, cfg,
                     EngineConfig(num_shards=3, cycles_per_dispatch=2,
                                  halo_slack=2.0))
    est = eng.init(inputs, seed=0, alive=dyn.present.copy())
    ta = lss.TopoArrays.from_topology(dyn)
    core = lss.init_state(ta, inputs, seed=0, alive=dyn.present.copy())

    rng = np.random.default_rng(4)
    ver = dyn.version
    for round_ in range(6):
        # Membership delta between dispatches, mirrored on both paths.
        _mutate(dyn, rng, 3)
        events = dyn.events_since(ver)
        ver = dyn.version
        rows, slots = [], []
        joins, leaves = [], []
        for ev in events:
            if ev.kind in ("link", "unlink"):
                rows += [ev.a, ev.b]
                slots += [ev.slot_a, ev.slot_b]
            elif ev.kind == "join":
                joins.append(ev.a)
            else:
                leaves.append(ev.a)
        eng.apply_membership(dyn)
        ta = lss.TopoArrays.from_topology(dyn)
        if rows:
            est = eng.clear_slots(est, rows, slots)
            core = lss.clear_slots(core, rows, slots)
        for p in leaves:
            est = eng.set_alive(est, [p], False)
            core = core._replace(alive=core.alive.at[p].set(False))
        for p in joins:
            est = eng.set_alive(est, [p], True)
            core = core._replace(alive=core.alive.at[p].set(True))

        est = eng.run(est, 4)
        for _ in range(4):
            core, _ = lss.cycle(core, ta, centers, cfg)
        un = eng.to_lss_state(est)
        np.testing.assert_allclose(un.out_m, core.out_m, atol=1e-6)
        np.testing.assert_allclose(un.in_m, core.in_m, atol=1e-6)
        np.testing.assert_allclose(un.out_c, core.out_c, atol=1e-6)
        assert np.array_equal(np.asarray(un.pending),
                              np.asarray(core.pending))
        assert np.array_equal(np.asarray(un.alive), np.asarray(core.alive))
        assert np.array_equal(np.asarray(un.last_send),
                              np.asarray(core.last_send))
        assert int(un.msgs) == int(core.msgs), round_
        acc_e, q_e, _ = eng.metrics(est)
        acc_c, q_c, _ = lss.metrics(core, ta, centers)
        assert float(acc_e) == float(acc_c) and bool(q_e) == bool(q_c)


def test_engine_membership_zero_recompile_within_headroom():
    dyn = DynTopology.from_topology(topology.grid(25), n_cap=28, deg_cap=6)
    centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=28, seed=1))
    x = sample(np.random.default_rng(5), 28)
    inputs = wvs.from_vector(jnp.asarray(x), jnp.ones((28,), jnp.float32))
    eng = ShardedLSS(dyn, centers, lss.LSSConfig(),
                     EngineConfig(num_shards=2, cycles_per_dispatch=4,
                                  halo_slack=2.0))
    est = eng.init(inputs, seed=0, alive=dyn.present.copy())
    est = eng.run(est, 4)  # warm
    warm = jit_cache_size(eng._run_jit)
    if warm is None:
        pytest.skip("jit cache stats unavailable on this jax")

    p = dyn.add_peer()
    dyn.add_edge(p, 0)
    dyn.add_edge(p, 24)
    dyn.remove_peer(12)
    reshaped = eng.apply_membership(dyn)
    assert not reshaped  # within halo headroom: data-only
    est = eng.set_alive(est, [p], True)
    est = eng.set_alive(est, [12], False)
    est = eng.run(est, 8)
    assert jit_cache_size(eng._run_jit) == warm


def test_device_tables_do_not_alias_mutable_buffers():
    """CPU jax may zero-copy-alias numpy memory on transfer; DynTopology
    mutates its numpy arrays in place.  A device-side table built before
    a mutation must keep its pre-mutation contents — an aliased buffer
    lets asynchronously executing dispatches read post-mutation data
    (a real, nondeterministic corruption this test pins down)."""
    dyn = DynTopology.from_topology(topology.grid(16), deg_cap=6)
    ta = lss.TopoArrays.from_topology(dyn)
    mask0 = np.asarray(ta.mask).copy()
    nbr0 = np.asarray(ta.nbr).copy()
    dyn.remove_edge(0, 1)
    dyn.add_edge(0, 5)
    assert np.array_equal(np.asarray(ta.mask), mask0)
    assert np.array_equal(np.asarray(ta.nbr), nbr0)


def test_collective_membership_parity(subproc):
    """Membership delta mid-run through shard_map + all_to_all on a real
    4-device mesh stays cycle-for-cycle identical to the core loop."""
    out = subproc("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import lss, sim, topology, wvs
from repro.engine import ShardedLSS, EngineConfig

dyn = topology.DynTopology.from_topology(topology.grid(64), n_cap=68,
                                         deg_cap=6)
centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=68, seed=0))
x = sample(np.random.default_rng(1), dyn.n)
inputs = wvs.from_vector(jnp.asarray(x), jnp.ones((dyn.n,), jnp.float32))
cfg = lss.LSSConfig()
mesh = jax.make_mesh((4,), ("shards",))
eng = ShardedLSS(dyn, centers, cfg,
                 EngineConfig(num_shards=4, cycles_per_dispatch=2,
                              halo_slack=2.0)).use_mesh(mesh, "shards")
est = eng.init(inputs, seed=0, alive=dyn.present.copy())
ta = lss.TopoArrays.from_topology(dyn)
core = lss.init_state(ta, inputs, seed=0, alive=dyn.present.copy())
est = eng.run(est, 6)
for _ in range(6):
    core, _ = lss.cycle(core, ta, centers, cfg)

ver = dyn.version
p = dyn.add_peer(); dyn.add_edge(p, 0); dyn.add_edge(p, 37)
dyn.remove_peer(22)
rows, slots = [], []
for ev in dyn.events_since(ver):
    if ev.kind in ("link", "unlink"):
        rows += [ev.a, ev.b]; slots += [ev.slot_a, ev.slot_b]
eng.apply_membership(dyn)
ta = lss.TopoArrays.from_topology(dyn)
est = eng.clear_slots(est, rows, slots)
core = lss.clear_slots(core, rows, slots)
est = eng.set_alive(est, [p], True)
core = core._replace(alive=core.alive.at[p].set(True))
est = eng.set_alive(est, [22], False)
core = core._replace(alive=core.alive.at[22].set(False))

est = eng.run(est, 8)
for _ in range(8):
    core, _ = lss.cycle(core, ta, centers, cfg)
un = eng.to_lss_state(est)
assert np.allclose(un.out_m, core.out_m, atol=1e-6)
assert np.allclose(un.in_m, core.in_m, atol=1e-6)
assert np.array_equal(np.asarray(un.pending), np.asarray(core.pending))
assert np.array_equal(np.asarray(un.alive), np.asarray(core.alive))
assert int(un.msgs) == int(core.msgs)
print("COLLECTIVE_MEMBERSHIP_OK")
""", n_devices=4)
    assert "COLLECTIVE_MEMBERSHIP_OK" in out


# ---------------------------------------------------------------------------
# service: membership at dispatch boundaries
# ---------------------------------------------------------------------------


def _service_problem(n_cap, seed=0):
    centers, sample, _, _ = sim.make_problem(
        sim.ProblemSpec(n=n_cap, seed=seed))
    x = sample(np.random.default_rng(seed + 1), n_cap)
    return np.asarray(centers), x


@pytest.mark.parametrize("backend", ["core", "engine"])
def test_service_membership_parity_with_manual_loop(backend):
    """One tenant, joins/leaves/links on a schedule: the service's
    per-dispatch records and full state match a hand-rolled core loop
    applying the same events at the same boundaries."""
    n_cap = 40
    base = topology.grid(36)
    dyn = DynTopology.from_topology(base, n_cap=n_cap, deg_cap=6,
                                    strict=True)
    centers, x = _service_problem(n_cap, seed=4)
    k = 3
    svc = Service(dyn, ServiceConfig(capacity=3, k_max=3, d=2,
                                     cycles_per_dispatch=k, backend=backend,
                                     engine_shards=2))
    qid = svc.admit(QuerySpec(region=regions.VoronoiRegions(
        jnp.asarray(centers)), inputs=x, seed=0))

    # The reference: a second DynTopology fed the same schedule by hand.
    ref = DynTopology.from_topology(base, n_cap=n_cap, deg_cap=6)
    ta = lss.TopoArrays.from_topology(ref)
    inputs = wvs.from_vector(jnp.asarray(x), jnp.ones((n_cap,), jnp.float32))
    st = lss.init_state(ta, inputs, seed=0, alive=ref.present.copy())
    cfg = lss.LSSConfig()
    decide = lambda v: regions.decide_voronoi(v, jnp.asarray(centers))

    # dispatch index -> [(method, args, join value)]
    schedule = {
        1: [("join", (36,), np.array([0.5, -0.25], np.float32)),
            ("link", (36, 0), None), ("link", (36, 7), None)],
        2: [("leave", (14,), None)],
        4: [("join", (37,), None), ("link", (37, 36), None),
            ("unlink", (0, 1), None)],
    }
    for disp in range(6):
        events = schedule.get(disp, [])
        for kind, args, value in events:
            if kind == "join":
                svc.join_peer(args[0], value=value)
            elif kind == "leave":
                svc.leave_peer(*args)
            elif kind == "link":
                svc.link_peers(*args)
            else:
                svc.unlink_peers(*args)
        (rec,) = svc.tick()

        # Mirror on the reference loop.
        ver = ref.version
        for kind, args, value in events:
            if kind == "join":
                ref.add_peer(args[0])
            elif kind == "leave":
                ref.remove_peer(*args)
            elif kind == "link":
                ref.add_edge(*args)
            else:
                ref.remove_edge(*args)
        evs = ref.events_since(ver)
        if evs:
            ta = lss.TopoArrays.from_topology(ref)
            rows, slots = [], []
            for ev in evs:
                if ev.kind in ("link", "unlink"):
                    rows += [ev.a, ev.b]
                    slots += [ev.slot_a, ev.slot_b]
            if rows:
                st = lss.clear_slots(st, rows, slots)
            for kind, args, value in events:
                if kind == "join":
                    p = args[0]
                    v = (np.zeros(2, np.float32) if value is None else value)
                    st = st._replace(
                        alive=st.alive.at[p].set(True),
                        x_m=st.x_m.at[p].set(jnp.asarray(v)),
                        x_c=st.x_c.at[p].set(1.0),
                        last_send=st.last_send.at[p].set(-(10 ** 6)))
                elif kind == "leave":
                    st = st._replace(alive=st.alive.at[args[0]].set(False))
        for _ in range(k):
            st, _ = lss.cycle(st, ta, centers=jnp.asarray(centers), cfg=cfg)

        snap = svc.snapshot(qid)
        np.testing.assert_allclose(snap.out_m, st.out_m, atol=1e-5)
        np.testing.assert_allclose(snap.in_m, st.in_m, atol=1e-5)
        np.testing.assert_allclose(snap.x_m, st.x_m, atol=1e-6)
        assert np.array_equal(np.asarray(snap.alive), np.asarray(st.alive))
        assert np.array_equal(np.asarray(snap.pending),
                              np.asarray(st.pending))
        assert np.array_equal(np.asarray(snap.last_send),
                              np.asarray(st.last_send))
        acc, q, _ = lss.metrics(st, ta, jnp.asarray(centers))
        assert rec["accuracy"] == float(acc)
        assert rec["quiescent"] == bool(q)
        assert rec["topo_version"] == ref.version
    assert svc.total_msgs(qid) == int(st.msgs)


def test_service_membership_zero_recompile_and_padding_silence():
    """Joins/leaves at boundaries must not recompile the batched step and
    must leave padding slots perfectly silent."""
    n_cap = 30
    dyn = DynTopology.from_topology(topology.grid(25), n_cap=n_cap,
                                    deg_cap=6)
    centers, x = _service_problem(n_cap, seed=2)
    svc = Service(dyn, ServiceConfig(capacity=4, k_max=3, d=2,
                                     cycles_per_dispatch=2))
    svc.admit(QuerySpec(region=regions.VoronoiRegions(jnp.asarray(centers)),
                        inputs=x, seed=0))
    svc.tick()  # warm
    warm = jit_cache_size(svc._step)

    p = svc.join_peer(value=[0.1, 0.2])
    svc.link_peers(p, 0)
    svc.tick()
    svc.leave_peer(3)
    svc.tick()
    assert svc.topo_version == dyn.version
    if warm is not None:
        assert jit_cache_size(svc._step) == warm
    # Padding slots: still zero messages, zero pending.
    assert all(int(m) == 0 for m in svc.backend.msgs_of(svc.states)[1:])
    assert not bool(jnp.any(svc.states.pending[1:]))


def test_service_membership_preserves_other_tenants_convergence():
    """A membership event must not reset in-flight tenants: their state
    carries over, and they re-converge to a genuine stopping state."""
    n_cap = 40
    dyn = DynTopology.from_topology(topology.grid(36), n_cap=n_cap,
                                    deg_cap=6)
    centers, x = _service_problem(n_cap, seed=6)
    svc = Service(dyn, ServiceConfig(capacity=3, k_max=3, d=2,
                                     cycles_per_dispatch=4))
    qa = svc.admit(QuerySpec(region=regions.VoronoiRegions(
        jnp.asarray(centers)), inputs=x, seed=0))
    for _ in range(10):
        (rec,) = svc.tick()
        if rec["quiescent"]:
            break
    assert rec["quiescent"]
    cycles_before = rec["t"]

    p = svc.join_peer(value=x[36])
    svc.link_peers(p, 5)
    svc.link_peers(p, 11)
    recs = [svc.tick()[0] for _ in range(12)]
    # The tenant kept its timeline (no reset to t=0)...
    assert recs[0]["t"] == cycles_before + 4
    assert recs[0]["topo_version"] == dyn.version
    # ...and re-converged around the new membership.
    assert recs[-1]["quiescent"] and recs[-1]["accuracy"] == 1.0


def test_membership_requires_dyn_topology():
    topo = topology.grid(25)
    centers, x = _service_problem(25, seed=1)
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2))
    with pytest.raises(RuntimeError, match="DynTopology"):
        svc.join_peer()


def test_membership_drain_survives_bad_event():
    """A queued event that fails at the boundary (here: raced by a direct
    DynTopology mutation) is dropped and recorded — the events queued
    behind it must still apply, with their join values intact."""
    dyn = DynTopology.from_topology(topology.grid(16), n_cap=18, deg_cap=6)
    centers, x = _service_problem(18, seed=1)
    svc = Service(dyn, ServiceConfig(capacity=2, k_max=3, d=2,
                                     cycles_per_dispatch=1))
    qa = svc.admit(_spec(centers, x, 0))
    svc.link_peers(0, 5)
    dyn.add_edge(0, 5)  # race: the queued link is now a duplicate
    p = svc.join_peer(value=[2.5, -1.5])
    svc.link_peers(p, 3)
    svc.tick()
    assert len(svc.membership.failures) == 1
    ev, msg = svc.membership.failures[0]
    assert ev.kind == "link" and "exists" in msg
    # The join behind the bad event landed, knowledge-init value intact.
    assert dyn.present[p] and dyn.has_edge(p, 3)
    snap = svc.snapshot(qa)
    np.testing.assert_allclose(np.asarray(snap.x_m)[p], [2.5, -1.5])
    assert bool(np.asarray(snap.alive)[p])
    # And eager validation catches the plain duplicate at the call site.
    with pytest.raises(ValueError, match="exists"):
        svc.link_peers(p, 3)
    with pytest.raises(ValueError, match="exists"):
        svc.link_peers(0, 1)  # pre-existing grid edge


def test_membership_queue_validates_eagerly():
    dyn = DynTopology.from_topology(topology.grid(16), n_cap=18)
    centers, x = _service_problem(18, seed=1)
    svc = Service(dyn, ServiceConfig(capacity=2, k_max=3, d=2))
    p = svc.join_peer()
    assert p == 16
    with pytest.raises(ValueError):
        svc.join_peer(p)  # row already claimed by the queued join
    q = svc.join_peer()
    assert q == 17
    with pytest.raises(ValueError):
        svc.join_peer()  # capacity exhausted including queued joins
    svc.leave_peer(3)
    with pytest.raises(ValueError):
        svc.link_peers(3, 0)  # 3 is leaving
    with pytest.raises(ValueError):
        svc.join_peer(value=[1.0, 2.0, 3.0])  # wrong d


# ---------------------------------------------------------------------------
# admission backpressure
# ---------------------------------------------------------------------------


def _spec(centers, x, seed=0):
    return QuerySpec(region=regions.VoronoiRegions(jnp.asarray(centers)),
                     inputs=x, seed=seed)


def test_admission_queue_drains_as_slots_free():
    topo = topology.grid(25)
    centers, x = _service_problem(25, seed=3)
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2,
                                      cycles_per_dispatch=1,
                                      admission_queue=3))
    a = svc.admit(_spec(centers, x, 0))
    b = svc.admit(_spec(centers, x, 1))
    c = svc.admit(_spec(centers, x, 2))  # queued (FIFO head)
    d = svc.admit(_spec(centers, x, 3))  # queued
    assert svc.admission_status(a) == "active"
    assert svc.admission_status(c) == "queued"
    assert svc.admission_status(d) == "queued"
    svc.tick()  # queued specs stay queued while slots are full
    assert svc.admission_status(c) == "queued"

    svc.retire(a)  # frees a slot -> c activates immediately, FIFO order
    assert svc.admission_status(c) == "active"
    assert svc.admission_status(d) == "queued"
    svc.retire(b)
    assert svc.admission_status(d) == "active"
    (r1, r2) = sorted(svc.tick(), key=lambda r: r["query"])
    assert {r1["query"], r2["query"]} == {c, d}
    # Lifecycle statuses stay queryable after the slot is gone.
    assert svc.admission_status(a) == "retired"
    with pytest.raises(KeyError):
        svc.admission_status("nope")
    # A queued admission that is retired before activation is cancelled.
    e = svc.admit(_spec(centers, x, 4))
    f = svc.admit(_spec(centers, x, 5))
    assert svc.admission_status(f) == "queued"
    svc.retire(f)
    assert svc.admission_status(f) == "cancelled"
    del e


def test_admission_overflow_policies():
    topo = topology.grid(25)
    centers, x = _service_problem(25, seed=3)
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      admission_queue=1,
                                      admission_overflow="reject"))
    svc.admit(_spec(centers, x, 0))
    svc.admit(_spec(centers, x, 1))  # queued
    with pytest.raises(RuntimeError, match="admission"):
        svc.admit(_spec(centers, x, 2))  # queue full, reject policy

    svc2 = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                       admission_queue=1,
                                       admission_overflow="evict-oldest"))
    svc2.admit(_spec(centers, x, 0))
    old = svc2.admit(_spec(centers, x, 1))
    new = svc2.admit(_spec(centers, x, 2))  # evicts `old`
    assert svc2.admission_status(old) == "evicted"
    assert svc2.admission_status(new) == "queued"

    # Duplicate ids are rejected across slots AND queue.
    with pytest.raises(ValueError):
        svc2.admit(_spec(centers, x, 3), query_id=new)


def test_admission_queue_rejects_bad_specs_eagerly():
    topo = topology.grid(25)
    centers, x = _service_problem(25, seed=3)
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      admission_queue=4))
    svc.admit(_spec(centers, x, 0))
    with pytest.raises(ValueError):
        svc.admit(QuerySpec(region=regions.VoronoiRegions(
            jnp.asarray(centers)), inputs=x[:10]))  # wrong peer count
    with pytest.raises(ValueError):
        svc.admit(QuerySpec(region=regions.VoronoiRegions(
            jnp.asarray(centers)), inputs=np.zeros((25, 5), np.float32)))
