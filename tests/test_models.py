"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and absence of NaNs (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cfgs
from repro.models import EncDecConfig, build


@pytest.mark.parametrize("arch_id", cfgs.ARCH_IDS)
def test_arch_smoke_forward_and_shapes(arch_id):
    cfg = cfgs.get_smoke(arch_id)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, L = 2, 32
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab)
    if isinstance(cfg, EncDecConfig):
        frames = jax.random.normal(key, (B, cfg.enc_len, cfg.d_model))
        loss, aux = jax.jit(model.loss)(params, frames, toks, toks)
    else:
        logits, _ = model.logits_train(params, toks)
        assert logits.shape == (B, L, cfg.vocab)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
        loss, aux = jax.jit(model.loss)(params, toks, toks)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # rough sanity: loss close to uniform log(vocab) at init
    assert abs(float(loss) - np.log(cfg.vocab)) < 2.5


@pytest.mark.parametrize("arch_id", cfgs.ARCH_IDS)
def test_arch_smoke_train_step(arch_id):
    """One real optimizer step on a 1-device mesh (full step machinery)."""
    from repro.configs import ShapeCell
    from repro.training.steps import TrainHParams, build_for_cell

    cfg = cfgs.get_smoke(arch_id)
    model = build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cell = ShapeCell("t", "train", 32, 2)
    with mesh:
        step, _, _, input_specs = build_for_cell(model, mesh, cell,
                                                 TrainHParams(accum_steps=2))
        params = model.init(jax.random.PRNGKey(0))
        from repro.optim import adamw_init
        opt = adamw_init(params)
        key = jax.random.PRNGKey(1)
        batch = {
            "tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab),
            "labels": jax.random.randint(key, (2, 32), 0, cfg.vocab),
        }
        if isinstance(cfg, EncDecConfig):
            batch["frames"] = jax.random.normal(
                key, (2, cfg.enc_len, cfg.d_model), jnp.float32)
        params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["gnorm"]))
    assert int(opt2.step) == 1
    # optimizer moments are non-zero after the step (the update ran)
    m_norm = sum(float(jnp.sum(jnp.abs(m))) for m in jax.tree.leaves(opt2.m))
    assert m_norm > 0.0


@pytest.mark.parametrize("arch_id", ["qwen3-14b", "mixtral-8x7b",
                                     "mamba2-370m", "zamba2-2.7b"])
def test_decode_matches_teacher_forcing(arch_id):
    """Prefill(L) then decode produces the same next-token logits as a
    teacher-forced forward at position L (KV-cache correctness)."""
    cfg = cfgs.get_smoke(arch_id)
    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    B, L = 2, 16
    toks = jax.random.randint(key, (B, L + 1), 0, cfg.vocab)
    logits_tf, _ = model.logits_train(params, toks)
    want = logits_tf[:, L - 1]  # prediction after prefix of length L

    cache = model.init_cache(B, 64)
    logits_pf, cache = model.prefill(params, toks[:, :L], cache)
    np.testing.assert_allclose(np.asarray(logits_pf, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)
    # one decode step must match teacher forcing at position L
    logits_dec, _ = model.decode_step(params, toks[:, L], cache)
    want2 = model.logits_train(params, toks)[0][:, L]
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(want2, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_moe_aux_loss_and_routing():
    from repro.models import moe as moe_lib

    cfg = moe_lib.MoEConfig(d_model=32, d_ff=64, n_experts=4, top_k=2,
                            capacity_factor=2.0)
    key = jax.random.PRNGKey(0)
    params = moe_lib.init(key, cfg)
    x = jax.random.normal(key, (2, 16, 32))
    y, aux = moe_lib.fwd(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux["aux_loss"]) >= 1.0 - 1e-3  # >= 1 by Cauchy-Schwarz


def test_ssm_chunked_equals_stepwise():
    """SSD chunked dual form == token-by-token recurrence (same params)."""
    from repro.models import ssm as ssm_lib

    cfg = ssm_lib.SSMConfig(d_model=32, d_state=8, headdim=8, expand=2,
                            n_groups=1, chunk=8)
    key = jax.random.PRNGKey(0)
    params = ssm_lib.init(key, cfg)
    B, L = 2, 32
    x = jax.random.normal(key, (B, L, 32)) * 0.5
    y_chunk, final = ssm_lib.fwd_train(params, cfg, x)
    st = ssm_lib.init_state(cfg, B)
    ys = []
    for t in range(L):
        y_t, st = ssm_lib.fwd_decode(params, cfg, x[:, t:t + 1], st)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_step, np.float32),
                               atol=2e-3, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(final.ssm, np.float32),
                               np.asarray(st.ssm, np.float32),
                               atol=2e-3, rtol=2e-2)
