"""Observability layer: trackers, metrics, schema, spans, dashboards.

The contract under test: instrumentation is a pure observer.  Serving
with any tracker backend produces bitwise-identical results to serving
with none; every emitted record satisfies :mod:`repro.obs.schema`; the
host-boundary spans carry real timings; and control-plane policies (SLO
eviction) consume the shared metrics registry rather than private books.
"""

import io
import json
import time

import numpy as np
import pytest

from repro.core import regions, sim, topology
from repro.obs import (InMemoryTracker, JsonlTracker, MetricsRegistry,
                       NoopTracker, PrometheusTextTracker, jit_cache_size,
                       render_controls, render_dashboard, sparkline,
                       validate_record, validate_stream)
from repro.obs.validate import (_check_boundary_spans, _churn_run,
                                validate_file)
from repro.service import (ControlPlaneConfig, QuerySpec, Service,
                           ServiceConfig, SLOSpec, TelemetrySink,
                           heterogeneous_tenants)
from repro.service.controlplane import SLOEvictionPolicy

import jax.numpy as jnp


def _specs(n, q, seed=3):
    centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=n, seed=seed))
    rng = np.random.default_rng(seed + 1)
    return [QuerySpec(region=regions.VoronoiRegions(jnp.asarray(centers)),
                      inputs=sample(rng, n), seed=i) for i in range(q)]


def _small_service(tracker=None, telemetry=None, backend="core", **cfg_kw):
    topo = topology.grid(36)
    kw = dict(capacity=3, k_max=3, d=2, cycles_per_dispatch=2)
    if backend == "engine":
        kw.update(backend="engine", engine_shards=2)
    kw.update(cfg_kw)
    svc = Service(topo, ServiceConfig(**kw), tracker=tracker,
                  telemetry=telemetry)
    for s in _specs(topo.n, 3):
        svc.admit(s)
    return svc


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_units():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    c.inc(query="a")
    c.inc(2, query="a")
    c.inc(query="b")
    assert c.value(query="a") == 3.0
    assert c.value(query="b") == 1.0
    assert c.value(query="zzz") == 0.0  # counters default to 0
    with pytest.raises(ValueError):
        c.inc(-1)  # counters only go up

    g = reg.gauge("depth")
    assert g.value() is None  # gauges are unset until written
    g.set(4)
    g.inc(1.5)
    assert g.value() == 5.5
    assert g.remove() and g.value() is None

    h = reg.histogram("lat", buckets=(1.0, 10.0))
    for v in (0.5, 5.0, 50.0):
        h.observe(v, span="x")
    assert h.count(span="x") == 3
    assert h.total(span="x") == 55.5
    assert h.mean(span="x") == pytest.approx(18.5)
    ((labels, (counts, _)),) = list(h.series())
    assert labels == {"span": "x"}
    assert counts == [1, 1, 1]  # one per bucket (cumulated at exposition)


def test_registry_idempotent_and_kind_checked():
    reg = MetricsRegistry()
    a = reg.counter("x", "first")
    assert reg.counter("x") is a  # same instrument back
    with pytest.raises(TypeError):
        reg.gauge("x")  # same name, different kind
    assert reg.get("x") is a and reg.get("nope") is None
    a.inc(query="q1")
    reg.gauge("y").set(1.0, query="q1")
    assert reg.remove_labels(query="q1") == 2  # scrubbed from every metric
    assert a.value(query="q1") == 0.0


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("msgs_total", "messages").inc(3, query="q1")
    reg.gauge("depth", "queue depth").set(2)
    reg.histogram("lat", "latency", buckets=(0.1,)).observe(0.05)
    text = reg.prometheus_text()
    assert "# HELP msgs_total messages" in text
    assert "# TYPE msgs_total counter" in text
    assert 'msgs_total{query="q1"} 3' in text
    assert "depth 2" in text
    assert 'lat_bucket{le="0.1"} 1' in text
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_sum 0.05" in text and "lat_count 1" in text


def test_histogram_bucket_exposition_is_cumulative():
    """Pin Prometheus histogram semantics: ``_bucket`` series are
    CUMULATIVE counts (each ``le`` bound includes every smaller bucket),
    ending at ``+Inf == _count`` — even though the in-memory counts are
    per-bucket."""
    reg = MetricsRegistry()
    h = reg.histogram("lat", "latency", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 5.0, 50.0, 500.0):
        h.observe(v, span="x")
    ((_, (counts, _total)),) = list(h.series())
    assert counts == [1, 2, 1, 1]  # raw per-bucket, NOT cumulative
    lines = reg.prometheus_text().splitlines()
    buckets = [l for l in lines if l.startswith("lat_bucket")]
    assert buckets == [
        'lat_bucket{span="x",le="1"} 1',
        'lat_bucket{span="x",le="10"} 3',
        'lat_bucket{span="x",le="100"} 4',
        'lat_bucket{span="x",le="+Inf"} 5',
    ]
    assert 'lat_count{span="x"} 5' in lines


def test_histogram_percentiles():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 10.0))
    assert h.p50() is None  # no samples yet
    for v in (0.5, 5.0, 50.0):
        h.observe(v, span="x")
    # rank interpolation inside the (1, 10] bucket
    assert h.p50(span="x") == pytest.approx(5.5)
    assert h.percentile(10.0, span="x") < 1.0
    # ranks past the last finite bound clamp to it (never invented)
    assert h.p95(span="x") == 10.0
    assert h.p99(span="x") == 10.0
    with pytest.raises(ValueError):
        h.percentile(0.0, span="x")
    with pytest.raises(ValueError):
        h.percentile(100.0, span="x")


def test_alert_rule_percentile_selection():
    """A histogram rule with ``percentile=`` fires on the tail, not the
    mean — and stamps the percentile into the alert record."""
    from repro.obs import AlertEngine, AlertRule

    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 10.0))
    # 9 fast samples, 1 slow: mean ~5.4, p95 = 10 (clamped tail).
    for _ in range(9):
        h.observe(0.5, span="x")
    h.observe(50.0, span="x")
    mean_rule = AlertRule("mean_hi", "lat", above=8.0)
    tail_rule = AlertRule("tail_hi", "lat", above=8.0, percentile=95.0)
    eng = AlertEngine([mean_rule, tail_rule], reg)
    recs = eng.evaluate(dispatch=1, t=1)
    assert [r["rule"] for r in recs] == ["tail_hi"]  # mean hides the tail
    assert recs[0]["percentile"] == 95.0
    assert validate_record(recs[0]) == []


# ---------------------------------------------------------------------------
# trackers
# ---------------------------------------------------------------------------


def test_spans_timed_even_under_noop():
    for tracker in (NoopTracker(), InMemoryTracker()):
        with tracker.span("work", k=4) as sp:
            time.sleep(0.002)
            sp.set("extra", 1)
        assert sp.seconds > 0.0
        assert sp.attrs == {"k": 4, "extra": 1}
    # InMemory kept the span and fed the histogram; Noop kept nothing.
    assert tracker.spans_named("work")[0] is sp
    assert tracker.registry.get("span_seconds").count(span="work") == 1
    noop = NoopTracker()
    with noop.span("work"):
        pass
    assert noop.registry.names() == []


def test_jsonl_ring_buffer_file_gets_everything(tmp_path):
    path = str(tmp_path / "t.jsonl")
    with JsonlTracker(path, max_records=2) as tr:
        for i in range(5):
            tr.log_record({"kind": "control", "dispatch": i, "t": i,
                           "queue_depth": 0, "preempted_depth": 0})
    assert [r["dispatch"] for r in tr.records] == [3, 4]  # bounded memory
    lines = [json.loads(l) for l in open(path)]
    assert [r["dispatch"] for r in lines] == [0, 1, 2, 3, 4]  # full file


def test_tracker_close_is_deterministic_and_idempotent(tmp_path):
    path = str(tmp_path / "t.jsonl")
    tr = JsonlTracker(path)
    tr.log_record({"kind": "control", "dispatch": 0, "t": 0,
                   "queue_depth": 0, "preempted_depth": 0})
    tr.close()
    tr.close()  # idempotent
    assert len(open(path).readlines()) == 1
    # Borrowed file-like: flushed but NOT closed by the tracker.
    buf = io.StringIO()
    with JsonlTracker(buf) as tr2:
        tr2.log_record({"kind": "control", "dispatch": 1, "t": 1,
                        "queue_depth": 0, "preempted_depth": 0})
    assert not buf.closed and buf.getvalue().count("\n") == 1


def test_telemetry_sink_is_a_jsonl_tracker(tmp_path):
    """The legacy sink is a thin shim: same type, same bytes, bounded."""
    path = str(tmp_path / "sink.jsonl")
    sink = TelemetrySink(path=path, max_records=3)
    assert isinstance(sink, JsonlTracker)
    rec = {"kind": "control", "dispatch": 0, "t": 4,
           "queue_depth": 1, "preempted_depth": 0}
    sink.emit(rec)  # legacy spelling of log_record
    sink.close()
    assert open(path).read() == json.dumps(rec) + "\n"
    for i in range(10):
        TelemetrySink(max_records=3).emit(dict(rec, dispatch=i))
    mem = TelemetrySink(max_records=3)
    for i in range(10):
        mem.emit(dict(rec, dispatch=i))
    assert len(mem.records) == 3  # unbounded-growth bug is gone


def test_prometheus_tracker_counts_records():
    tr = PrometheusTextTracker()
    tr.log_record({"kind": "control"})
    tr.log_record({"query": "q1"})
    text = tr.expose()
    assert 'records_total{kind="control"} 1' in text
    assert 'records_total{kind="query"} 1' in text


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


def test_schema_validators():
    good_q = {"dispatch": 1, "t": 2, "query": "q0", "slot": 0,
              "accuracy": 1.0, "quiescent": True, "region": 1,
              "msgs": 3, "msgs_per_link": 0.1, "topo_version": 0,
              "trace_id": "t00001:q0"}
    good_c = {"kind": "control", "dispatch": 1, "t": 2, "queue_depth": 0,
              "preempted_depth": 0, "spans": {"dispatch": 0.1},
              "boundary": {"epochs": 1}}
    assert validate_record(good_q) == []
    assert validate_record(good_c) == []
    assert validate_record({**good_q, "accuracy": "high"})  # wrong type
    assert validate_record({**good_q, "mystery": 1})  # unknown key
    assert validate_record({"kind": "martian"})  # unknown kind
    missing = dict(good_c)
    del missing["queue_depth"]
    assert validate_record(missing)
    probs = validate_stream([good_q, {**good_q, "quiescent": 1}])
    assert [i for i, _ in probs] == [1]  # bool-typed field rejects int


def test_golden_schema_core_backend():
    """Every record a core-backend service emits satisfies the schema —
    per-query and control, through the InMemory and Jsonl backends."""
    buf = io.StringIO()
    tr = JsonlTracker(buf)
    svc = _small_service(tracker=tr,
                         control=ControlPlaneConfig(scheduler="priority"))
    svc.serve(3)
    svc.push_updates(np.array([0, 1]), np.zeros((2, 2)), mode="set")
    svc.tick()
    svc.close()
    recs = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert validate_stream(recs) == []
    assert sum(r.get("kind") == "control" for r in recs) >= 1
    assert sum("query" in r for r in recs) == 4 * 3  # 4 dispatches x 3 slots


def test_golden_schema_engine_backend_and_halo_metric():
    tr = InMemoryTracker()
    svc = _small_service(tracker=tr, backend="engine")
    svc.serve(2)
    assert validate_stream(tr.records) == []
    halo = tr.registry.get("engine_halo_bytes_total")
    assert halo is not None and halo.value() > 0  # engine feeds transport cost
    svc.close()


# ---------------------------------------------------------------------------
# tracking must not perturb serving
# ---------------------------------------------------------------------------


def test_tracking_on_off_bitwise_parity(tmp_path):
    """JsonlTracker-enabled serving is bitwise-identical to NoopTracker
    serving: same records (floats equal), same final state arrays."""
    def run(tracker):
        svc = _small_service(tracker=tracker)
        out = []
        rng = np.random.default_rng(0)
        for _ in range(4):
            who = rng.choice(svc.topo.n, size=3, replace=False)
            svc.push_updates(who, rng.normal(size=(who.size, 2)), mode="set")
            out.extend(svc.tick())
        states = svc.states
        svc.close()
        return out, states

    rec_off, st_off = run(NoopTracker())
    rec_on, st_on = run(JsonlTracker(str(tmp_path / "on.jsonl")))
    assert rec_on == rec_off  # exact equality, accuracy floats included
    for a, b in zip(st_on, st_off):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# spans + convergence metrics through a real service
# ---------------------------------------------------------------------------


def test_boundary_spans_nonzero_in_churn_run(tmp_path):
    """The acceptance gate: membership drain, admission drain, ingest
    staging, dispatch, and observe all appear with nonzero timings in
    the control records of a churn run (same run the CI validator does)."""
    path = str(tmp_path / "churn.jsonl")
    _churn_run(path)
    assert validate_file(path) == []
    assert _check_boundary_spans(path) == []
    ctrl = [json.loads(l) for l in open(path)
            if json.loads(l).get("kind") == "control"]
    assert any("boundary" in c and c["boundary"].get("epochs") for c in ctrl)


def test_convergence_metrics_fed_from_dispatch():
    tr = InMemoryTracker()
    svc = _small_service(tracker=tr)
    svc.serve(6)
    reg = tr.registry
    qid = svc.registry.active_items()[0][0]
    assert reg.gauge("tenant_accuracy").value(query=qid) is not None
    assert reg.counter("tenant_msgs_total").value(query=qid) >= 0
    hist = reg.get("service_corr_iters")
    assert hist is not None and hist.count(query=qid) == 6
    assert reg.gauge("service_active_slots").value() == 3
    # Quiescence time lands as a gauge once a tenant settles.
    if any(r["quiescent"] for r in tr.records if "query" in r):
        assert any(True for _ in reg.gauge("tenant_quiesced_at_cycles")
                   .series())
    svc.close()


def test_dispatch_info_counters():
    svc = _small_service()
    svc.tick()
    info = svc.dispatch_info()
    assert info["suite"] in ("reference", "fused")
    if jit_cache_size(svc._step) is None:
        pytest.skip("jit cache stats unavailable on this jax")
    assert info["recompiles"] >= 1  # the cold compile is counted
    assert info["step_cache_size"] == jit_cache_size(svc._step)
    svc.tick()
    assert svc.dispatch_info()["recompiles"] == info["recompiles"]  # steady
    svc.close()


# ---------------------------------------------------------------------------
# SLO-driven eviction (control plane consuming the registry)
# ---------------------------------------------------------------------------


def test_eviction_policy_reads_registry_only():
    reg = MetricsRegistry()
    pol = SLOEvictionPolicy(reg, attainment_below=0.5, min_windows=2)
    assert pol.victims(["a"]) == []  # nothing published yet
    reg.gauge("slo_attainment").set(0.1, query="a")
    reg.gauge("slo_evaluated").set(1, query="a")
    assert pol.victims(["a"]) == []  # too few windows to judge
    reg.gauge("slo_evaluated").set(2, query="a")
    ((qid, reason),) = pol.victims(["a"])
    assert qid == "a" and "attainment" in reason
    reg.gauge("slo_attainment").set(0.9, query="a")
    assert pol.victims(["a"]) == []  # healthy again
    assert SLOEvictionPolicy(reg, attainment_below=0.0).victims(["a"]) == []


def test_service_evicts_unrecoverable_waiters():
    """A queued tenant whose SLO deadline burns down past the attainment
    floor is evicted — visible in admission status AND the control trail."""
    topo = topology.grid(16)
    cp = ControlPlaneConfig(evict_attainment_below=0.5, evict_min_windows=2)
    tr = InMemoryTracker()
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      cycles_per_dispatch=2,
                                      admission_queue=4, control=cp),
                  tracker=tr)
    holder, waiter = _specs(topo.n, 2)
    import dataclasses
    waiter = dataclasses.replace(
        waiter, slo=SLOSpec(target_accuracy=0.99, within_cycles=2))
    svc.admit(holder)
    qid = svc.admit(waiter)  # no slot left: waits, burning its deadline
    for _ in range(5):
        svc.tick()
    assert svc.admission_status(qid) == "evicted"
    assert "attainment" in svc.admission.terminal_reason(qid)
    evicted = [e for c in tr.controls() for e in c.get("evicted", [])]
    assert [e["query"] for e in evicted] == [qid]
    svc.close()


# ---------------------------------------------------------------------------
# service tracker plumbing
# ---------------------------------------------------------------------------


def test_service_tracker_exclusive_and_owned_close(tmp_path):
    with pytest.raises(ValueError):
        _small_service(tracker=NoopTracker(),
                       telemetry=TelemetrySink())
    # Owned default sink: service closes it; bounded retention.
    svc = _small_service()
    assert isinstance(svc.telemetry, TelemetrySink)
    assert svc.telemetry is svc.tracker
    svc.tick()
    with svc:
        pass
    assert svc.tracker._closed
    # Borrowed tracker: service flushes but does not close it.
    tr = JsonlTracker(str(tmp_path / "b.jsonl"))
    svc2 = _small_service(tracker=tr)
    svc2.tick()
    svc2.close()
    assert not tr._closed
    tr.close()


# ---------------------------------------------------------------------------
# dashboard
# ---------------------------------------------------------------------------


def test_sparkline_and_dashboard_render():
    assert sparkline([]) == "···"  # placeholder, never raises
    line = sparkline([0.0, 0.5, 1.0], width=3)
    assert len(line) == 3 and line[0] == "▁" and line[-1] == "█"
    assert len(set(sparkline([0.7, 0.7], lo=None, hi=None))) == 1  # flat
    tr = InMemoryTracker()
    svc = _small_service(tracker=tr)
    svc.serve(4)
    qids = sorted({r["query"] for r in tr.records if "query" in r})
    dash = render_dashboard(tr.records)
    for qid in qids:
        assert qid in dash
    assert "acc" in dash
    ctrl = render_controls(tr.records)
    assert isinstance(ctrl, str)
    svc.close()
