"""Overlapped host boundary: double-buffered dispatch, staged epochs.

``ServiceConfig(overlap=True)`` pipelines the service loop: tick K+1's
host boundary (membership drain, admission, ingest) runs while dispatch
K's device work is still in flight, and dispatch K's telemetry is
finished one tick later off its :class:`~repro.service.overlap.
PendingWindow`.  The contracts under test:

* record CONTENT is bitwise identical to synchronous mode under full
  churn + ingest load, on both backends — only *emission* is deferred
  by one tick (``flush()``/``serve()`` drain the last window);
* steady-state overlap stays zero-recompile: the
  :class:`~repro.service.overlap.DoubleBuffer` canary proves every
  swapped operand keeps its traced (shape, dtype) signature, and an
  undeclared reshape raises :class:`~repro.service.overlap.
  BufferReshape` instead of silently recompiling;
* a preempted tenant's targeted ingest is parked and replayed at
  resume, not dropped;
* staged epochs (background partition builds) adopt prebuilt engines
  bitwise-equivalently to the synchronous rebuild, including journal
  catch-up for membership applied while the build was staged;
* :class:`~repro.obs.ProfiledDispatch`'s ``sample_every`` fences only
  the sampled calls.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lss, regions, sim, topology
from repro.obs import InMemoryTracker, ProfiledDispatch, jit_cache_size
from repro.service import (ControlPlaneConfig, QuerySpec, Service,
                          ServiceConfig)
from repro.service.overlap import BufferReshape, DoubleBuffer, StagedBuild

DynTopology = topology.DynTopology


def _problem(n, seed=0):
    centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=n, seed=seed))
    x = sample(np.random.default_rng(seed + 1), n)
    return np.asarray(centers), x


def _spec(centers, x, seed=0, priority=0):
    return QuerySpec(region=regions.VoronoiRegions(jnp.asarray(centers)),
                     inputs=x, seed=seed, priority=priority)


def _padded_spec(centers, x, n_cap, seed=0):
    """Inputs sized to capacity: zero-weight padding rows (spare slots)."""
    n = x.shape[0]
    xx = np.zeros((n_cap, x.shape[1]), np.float32)
    xx[:n] = x
    w = np.zeros((n_cap,), np.float32)
    w[:n] = 1.0
    return QuerySpec(region=regions.VoronoiRegions(jnp.asarray(centers)),
                     inputs=xx, weights=w, seed=seed)


def _strip(rec):
    """Drop the per-service-instance identifier; everything else in a
    tenant record is part of the parity contract."""
    return {k: v for k, v in rec.items() if k != "trace_id"}


def _state_fields_equal(a: lss.LSSState, b: lss.LSSState, skip=()):
    for name in lss.LSSState._fields:
        if name in skip:
            continue
        av, bv = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert np.array_equal(av, bv), name


# ---------------------------------------------------------------------------
# record parity: overlap == sync, bitwise, under churn + ingest
# ---------------------------------------------------------------------------


def _run_churny(overlap, backend, ticks=6):
    """One service under the full boundary load: two tenants, streaming
    ingest, a leave and a join mid-serve.  Returns (records, snapshots).
    """
    base = topology.grid(36)
    centers, x = _problem(36, seed=11)
    dyn = DynTopology.from_topology(base, n_cap=40, deg_cap=6)
    svc = Service(dyn, ServiceConfig(
        capacity=2, k_max=3, d=2, cycles_per_dispatch=2, backend=backend,
        engine_shards=2, overlap=overlap))
    qa = svc.admit(_padded_spec(centers, x, 40, seed=0))
    qb = svc.admit(_padded_spec(centers, x, 40, seed=1))
    records = []
    for t in range(ticks):
        if t == 1:
            svc.push_updates([3, 5], [[0.9, 0.1], [0.2, 0.7]])
        if t == 2:
            svc.leave_peer(7)
        if t == 4:
            svc.join_peer(7, value=[0.4, 0.4])
            svc.link_peers(7, 8)
        records.extend(svc.tick())
    records.extend(svc.flush())
    snaps = {q: svc.snapshot(q) for q in (qa, qb)}
    svc.close()
    return records, snaps, (qa, qb)


@pytest.mark.parametrize("backend", ["core", "engine"])
def test_overlap_record_parity_under_churn_and_ingest(backend):
    """The acceptance gate: overlap mode's records are bitwise the sync
    mode's — same dispatch indices, same metrics, same message counts —
    under ingest, a leave, and a join; final slot states match too."""
    sync_recs, sync_snaps, qids = _run_churny(False, backend)
    over_recs, over_snaps, _ = _run_churny(True, backend)
    key = lambda r: (r["dispatch"], r["query"])
    assert len(sync_recs) == len(over_recs)
    for a, b in zip(sorted(sync_recs, key=key), sorted(over_recs, key=key)):
        assert _strip(a) == _strip(b)
    for q in qids:
        _state_fields_equal(sync_snaps[q], over_snaps[q])


def test_overlap_defers_emission_one_tick():
    """tick() under overlap returns the PREVIOUS window's records: the
    first tick emits nothing, each later tick emits dispatch K-1, and
    flush()/serve() drain the final in-flight window."""
    topo = topology.grid(25)
    centers, x = _problem(25, seed=3)
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      cycles_per_dispatch=2, overlap=True))
    svc.admit(_spec(centers, x))
    assert svc.tick() == []  # window 1 launched, nothing to emit yet
    (r1,) = svc.tick()
    assert r1["dispatch"] == 1  # one-tick deferral (sync numbering is 1-based)
    (r2,) = svc.flush()
    assert r2["dispatch"] == 2
    assert svc.flush() == []  # idempotent: nothing pending
    svc.close()

    # serve() self-drains: the trailing window is flushed, so the return
    # value is the FINAL dispatch's records in overlap mode too.
    svc2 = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                       cycles_per_dispatch=2, overlap=True))
    svc2.admit(_spec(centers, x))
    recs = svc2.serve(4)
    assert [r["dispatch"] for r in recs] == [4]
    assert svc2._pending is None  # nothing left in flight
    svc2.close()


# ---------------------------------------------------------------------------
# zero-recompile: the DoubleBuffer canary and steady-state jit cache
# ---------------------------------------------------------------------------


def test_double_buffer_canary_catches_undeclared_reshape():
    buf = DoubleBuffer()
    a = jnp.zeros((4, 2)), jnp.zeros((4,), jnp.int32)
    buf.swap(*a)
    buf.swap(jnp.ones((4, 2)), jnp.zeros((4,), jnp.int32))  # data-only: ok
    assert buf.swaps == 2 and buf.epochs == 0
    with pytest.raises(BufferReshape):
        buf.swap(jnp.zeros((5, 2)), jnp.zeros((4,), jnp.int32))
    with pytest.raises(BufferReshape):  # dtype change is a retrace too
        buf.swap(jnp.zeros((4, 2)), jnp.zeros((4,), jnp.float32))
    buf.invalidate()  # declared epoch: the new signature is adopted
    buf.swap(jnp.zeros((5, 2)), jnp.zeros((4,), jnp.int32))
    assert buf.epochs == 1


def test_overlap_steady_state_zero_recompile_under_churn():
    """After the warm-up dispatch, membership churn within capacity must
    not grow the jit cache in overlap mode — the double-buffered swap is
    data-only — while the buffer swap counter tracks every dispatch."""
    base = topology.grid(36)
    centers, x = _problem(36, seed=5)
    dyn = DynTopology.from_topology(base, n_cap=40, deg_cap=6)
    svc = Service(dyn, ServiceConfig(capacity=2, k_max=3, d=2,
                                     cycles_per_dispatch=2,
                                     backend="engine", engine_shards=2,
                                     overlap=True))
    svc.admit(_padded_spec(centers, x, 40, seed=0))
    svc.tick()  # warm-up: compiles the step
    before = jit_cache_size(svc._step_call)
    for t in range(4):
        if t == 0:
            svc.leave_peer(11)
        if t == 2:
            svc.join_peer(11, value=[0.3, 0.3])
            svc.link_peers(11, 12)
        svc.tick()
    svc.flush()
    after = jit_cache_size(svc._step_call)
    if before is not None and after is not None:
        assert after == before  # churn stayed data-only
    assert svc._buffers.swaps == 5
    assert svc._buffers.epochs == 0
    svc.close()


# ---------------------------------------------------------------------------
# preempted-tenant ingest: parked, replayed at resume, dropped at retire
# ---------------------------------------------------------------------------


def test_preempted_ingest_parks_and_replays_on_resume():
    """Targeted updates streamed at a preempted tenant buffer in the
    ingest parking lot and replay into its slot when it resumes — the
    suspension pauses the stream instead of losing it."""
    centers, x = _problem(25, seed=5)
    topo = topology.grid(25)
    cp = ControlPlaneConfig(scheduler="priority", preempt=True)
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      cycles_per_dispatch=2, control=cp))
    a = svc.admit(_spec(centers, x, seed=0, priority=0))
    svc.tick()
    b = svc.admit(_spec(centers, x, seed=1, priority=5))
    svc.tick()  # b preempts a
    assert svc.admission_status(a) == "preempted"

    svc.push_updates([3], [[9.0, 9.0]], query_ids=[a])
    svc.tick()  # boundary: the batch targets a suspended tenant -> parked
    assert svc.ingest.num_parked(a) == 1
    svc.push_updates([4], [[7.0, 7.0]], query_ids=[a])
    svc.tick()
    assert svc.ingest.num_parked(a) == 2

    svc.retire(b)  # frees the slot: a resumes, replaying its backlog
    assert svc.admission_status(a) == "active"
    assert svc.ingest.num_parked(a) == 0
    snap = svc.snapshot(a)
    np.testing.assert_array_equal(np.asarray(snap.x_m)[3], [9.0, 9.0])
    np.testing.assert_array_equal(np.asarray(snap.x_m)[4], [7.0, 7.0])
    np.testing.assert_array_equal(np.asarray(snap.x_c)[[3, 4]], [1.0, 1.0])
    svc.close()


def test_preempted_ingest_discarded_at_retire_and_bounded():
    centers, x = _problem(16, seed=2)
    topo = topology.grid(16)
    cp = ControlPlaneConfig(scheduler="priority")
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      cycles_per_dispatch=1, control=cp))
    a = svc.admit(_spec(centers, x, 0, priority=0))
    svc.admit(_spec(centers, x, 1, priority=4))
    svc.tick()
    assert svc.admission_status(a) == "preempted"
    svc.push_updates([2], [[1.0, 1.0]], query_ids=[a])
    svc.tick()
    assert svc.ingest.num_parked(a) == 1
    svc.retire(a)  # retiring a suspended tenant drops its backlog
    assert svc.ingest.num_parked(a) == 0
    svc.close()

    # The parking lot is bounded per tenant: oldest batches are shed.
    from repro.service import StreamIngest
    ing = StreamIngest(max_parked=2)
    for i in range(4):
        ing.park("q", ing.push([0], [[float(i), 0.0]], query_ids=("q",)))
        ing.drain()
    assert ing.num_parked("q") == 2
    assert ing.parked_dropped == 2
    got = ing.take_parked("q")
    assert [float(b.values[0, 0]) for b in got] == [2.0, 3.0]  # oldest shed


# ---------------------------------------------------------------------------
# staged epochs: background builds adopt bitwise
# ---------------------------------------------------------------------------


def test_staged_rebalance_adopts_prebuilt_engine_bitwise():
    """A rebalance epoch that adopts a background-staged partition build
    emits exactly what the synchronous rebuild emits (which itself is
    observable-invisible)."""
    base = topology.grid(36)
    centers, x = _problem(40, seed=9)

    def run(staged):
        dyn = DynTopology.from_topology(base, n_cap=40, deg_cap=6)
        svc = Service(dyn, ServiceConfig(
            capacity=2, k_max=3, d=2, cycles_per_dispatch=2,
            backend="engine", engine_shards=2))
        q = svc.admit(_padded_spec(centers, x, 40, seed=0))
        out = []
        for disp in range(6):
            if disp == 2:
                svc.join_peer(36, value=[0.2, 0.2])
                svc.link_peers(36, 7)
                svc.leave_peer(12)
            if disp == 3:
                if staged:
                    svc._staged["rebalance"] = \
                        svc.backend.stage_rebalance(svc._dyn)
                ev = svc.rebalance_now()
                assert ev is not None and ev["staged"] is staged
            out.extend(svc.tick())
        snap = svc.snapshot(q)
        svc.close()
        return out, snap

    recs_sync, snap_sync = run(False)
    recs_staged, snap_staged = run(True)
    assert len(recs_sync) == len(recs_staged) == 6
    for a, b in zip(recs_sync, recs_staged):
        assert _strip(a) == _strip(b)
    _state_fields_equal(snap_sync, snap_staged)


def test_staged_regrow_adopts_with_journal_catchup():
    """A regrow epoch adopting a build staged BEFORE further membership
    churn catches the prebuilt engine up from the topology journal
    (changed_rows_since the staged version) and matches the synchronous
    rebuild bitwise."""
    base = topology.grid(25)
    centers, x = _problem(26, seed=7)
    x26 = np.zeros((26, 2), np.float32)
    x26[:25] = x[:25]

    def run(staged):
        dyn = DynTopology.from_topology(base, n_cap=26, deg_cap=5)
        svc = Service(dyn, ServiceConfig(
            capacity=2, k_max=3, d=2, cycles_per_dispatch=2,
            backend="engine", engine_shards=2))
        spec = QuerySpec(region=regions.VoronoiRegions(jnp.asarray(centers)),
                         inputs=x26,
                         weights=np.r_[np.ones(25), 0.0].astype(np.float32),
                         seed=0)
        q = svc.admit(spec)
        out = [*svc.tick()]
        if staged:
            build, ver = svc.backend.stage_regrow(svc._dyn, n_cap=30,
                                                  deg_cap=5)
            svc._staged["regrow"] = (build, ver,
                                     {"n_cap": 30, "deg_cap": 5})
        # Membership applied AFTER staging: adoption must replay it onto
        # the prebuilt tables from the journal.
        svc.unlink_peers(3, 4)
        out.extend(svc.tick())
        svc.grow_capacity(n_cap=30, deg_cap=5)
        assert svc.capman.epochs[-1]["kind"] == "regrow"
        assert svc.capman.epochs[-1]["staged"] is staged
        svc.join_peer(26, value=[0.1, 0.1])
        svc.link_peers(26, 5)
        out.extend(svc.tick())
        out.extend(svc.tick())
        snap = svc.snapshot(q)
        svc.close()
        return out, snap

    recs_sync, snap_sync = run(False)
    recs_staged, snap_staged = run(True)
    assert len(recs_sync) == len(recs_staged)
    for a, b in zip(recs_sync, recs_staged):
        assert _strip(a) == _strip(b)
    _state_fields_equal(snap_sync, snap_staged)


def test_staged_build_surfaces_build_errors_at_take():
    def boom():
        raise RuntimeError("partition build failed")

    sb = StagedBuild(boom, label="rebalance")
    with pytest.raises(RuntimeError, match="partition build failed"):
        sb.take()  # take() joins, then re-raises the build error
    assert sb.ready()

    ok = StagedBuild(lambda: "engine", label="regrow")
    assert ok.take() == "engine"


# ---------------------------------------------------------------------------
# ProfiledDispatch overlap-aware sampling
# ---------------------------------------------------------------------------


def test_profiled_dispatch_sample_every_fences_sparsely():
    """sample_every=N fences (and publishes) only every Nth call; the
    unsampled calls hand back raw futures so overlap is preserved."""
    tr = InMemoryTracker()
    step = jax.jit(lambda v: v + 1)
    pd = ProfiledDispatch(step, tr, backend="test", sample_every=2)
    v = jnp.zeros((8,))
    for _ in range(5):
        v = pd(v)
    assert pd.calls == 5
    assert pd.sampled == 3  # calls 0, 2, 4
    assert float(v[0]) == 5.0  # unsampled calls still computed
    assert pd.last["host_overhead_frac"] >= 0.0
    # Only the fenced calls published attribution metrics.
    mine = [m for m in tr.metrics if m["labels"].get("backend") == "test"]
    assert len(mine) == 3
    assert all("dispatch_device_ms" in m["metrics"] for m in mine)


# ---------------------------------------------------------------------------
# observability staleness under overlap: window-scoped triggers and spans
# ---------------------------------------------------------------------------


def test_overlap_flight_dump_and_spans_use_window_dispatch(tmp_path):
    """Under overlap, window K's telemetry is finished while dispatch K+1
    is already live — so a flight-recorder trigger and the observe span
    must be stamped with the WINDOW's counters, not the service's.  The
    dump filename/header carry ``w.dispatch``/``w.t``, and every observe
    span's ``dispatch`` attr matches its window (one behind the tick root
    that finished it)."""
    import json
    import os

    from repro.obs import AlertRule

    topo = topology.grid(25)
    centers, x = _problem(25, seed=3)
    tr = InMemoryTracker()
    svc = Service(topo, ServiceConfig(
        capacity=1, k_max=3, d=2, cycles_per_dispatch=2, overlap=True,
        alerts=(AlertRule("always_on", "service_active_slots",
                          above=-1.0),),
        flight_dump_dir=str(tmp_path)), tracker=tr)
    svc.admit(_spec(centers, x))
    svc.tick()  # launches window 1; nothing finished yet -> no dump
    svc.tick()  # finishes window 1 while dispatch 2 is live -> alert dump
    # The trigger fired for window 1; the live counter already says 2.
    assert svc.dispatches == 2
    dumps = sorted(os.listdir(tmp_path))
    assert dumps == ["flight-d000001-alert.jsonl"]
    header = json.loads(
        open(os.path.join(tmp_path, dumps[0])).readline())
    assert header["dispatch"] == 1
    assert header["t"] == 2  # window 1 ran 2 cycles
    svc.flush()
    svc.close()

    # Span bookkeeping: each observe span is stamped with the window it
    # synced; under overlap that is one behind the tick that ran it
    # (except the flush tick, which IS its window's root).
    spans = [r for r in tr.records if r.get("kind") == "span"]
    ticks = {s["span_id"]: s for s in spans if s["name"] == "tick"}
    observes = [s for s in spans if s["name"] == "observe"]
    assert len(observes) == 2  # windows 1 and 2 both finished
    for obs_span in observes:
        parent = ticks[obs_span["parent_id"]]
        if parent["attrs"].get("flush"):
            assert obs_span["attrs"]["dispatch"] == \
                parent["attrs"]["dispatch"]
        else:
            assert obs_span["attrs"]["dispatch"] == \
                parent["attrs"]["dispatch"] - 1
    # Tick roots are labeled with the dispatch they RAN: 1, 2, then the
    # flush root re-labeled with the window it drained (2).
    assert [t["attrs"]["dispatch"] for t in
            sorted(ticks.values(), key=lambda s: s["span_id"])] == [1, 2, 2]


def test_sync_observe_span_matches_tick_dispatch():
    """In sync mode the observe span and its tick root agree on the
    dispatch index — the window is finished inside the tick that ran
    it."""
    topo = topology.grid(25)
    centers, x = _problem(25, seed=3)
    tr = InMemoryTracker()
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      cycles_per_dispatch=2), tracker=tr)
    svc.admit(_spec(centers, x))
    svc.tick()
    svc.tick()
    svc.close()
    spans = [r for r in tr.records if r.get("kind") == "span"]
    ticks = {s["span_id"]: s for s in spans if s["name"] == "tick"}
    observes = [s for s in spans if s["name"] == "observe"]
    assert len(observes) == 2
    for obs_span in observes:
        assert obs_span["attrs"]["dispatch"] == \
            ticks[obs_span["parent_id"]]["attrs"]["dispatch"]
