"""Multi-tenant monitor service: parity, masked slots, admission, ingest.

The service's contract mirrors the engine's: a query slot must reproduce
the single-query simulator *exactly* (same messages on the same cycles,
bitwise-identical decisions), with Q slots advancing through one vmapped
dispatch; padding slots must be true no-ops (zero effective messages).
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lss, regions, sim, stopping, topology, wvs
from repro.engine.sweep import sweep_configs, sweep_static
from repro.obs import jit_cache_size
from repro.service import (QuerySpec, Service, ServiceConfig, StreamIngest,
                           TelemetrySink)


def _problem(topo, seed=0):
    centers, sample, _, _ = sim.make_problem(
        sim.ProblemSpec(n=topo.n, seed=seed))
    rng = np.random.default_rng(seed + 1)
    return centers, sample(rng, topo.n)


def _decisions(state: lss.LSSState, topo_arrays, decide):
    """Per-peer region decisions f(vec(S_i)) — the service's output."""
    live = topo_arrays.mask & state.alive[:, None] & \
        state.alive[topo_arrays.nbr]
    s = stopping.status(state.x_m, state.x_c, state.out_m, state.out_c,
                        state.in_m, state.in_c, live)
    return np.asarray(decide(wvs.vec(s, 1e-9)))


def _assert_state_close(a: lss.LSSState, b: lss.LSSState, atol=1e-6):
    np.testing.assert_allclose(a.out_m, b.out_m, atol=atol)
    np.testing.assert_allclose(a.out_c, b.out_c, atol=atol)
    np.testing.assert_allclose(a.in_m, b.in_m, atol=atol)
    np.testing.assert_allclose(a.in_c, b.in_c, atol=atol)
    np.testing.assert_allclose(a.x_m, b.x_m, atol=atol)
    assert np.array_equal(np.asarray(a.pending), np.asarray(b.pending))
    assert np.array_equal(np.asarray(a.last_send), np.asarray(b.last_send))
    assert np.array_equal(np.asarray(a.alive), np.asarray(b.alive))


# ---------------------------------------------------------------------------
# packed region families
# ---------------------------------------------------------------------------


def test_packed_regions_decide_bitwise():
    """Padded Voronoi slots decide bitwise-identically to decide_voronoi;
    halfspace slots match HalfspaceRegions.decide."""
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(size=(500, 2)).astype(np.float32))
    fams = [
        regions.VoronoiRegions(jnp.asarray(
            rng.normal(size=(k, 2)).astype(np.float32)))
        for k in (2, 3, 4)
    ] + [regions.HalfspaceRegions(w=jnp.asarray([1.0, -0.5]),
                                  b=jnp.asarray(0.25))]
    packed = regions.PackedRegions.pack(fams)
    assert packed.k_max == 4 and packed.q == 4
    for i, fam in enumerate(fams):
        got = packed.decide_slot(i)(v)
        want = fam.decide(v)
        assert np.array_equal(np.asarray(got), np.asarray(want)), i
    # clear() turns the slot into an everything-is-region-0 padding family.
    cleared = packed.clear(1)
    assert (np.asarray(cleared.decide_slot(1)(v)) == 0).all()


def test_packed_regions_rejects_oversize_family():
    packed = regions.PackedRegions.empty(2, 3, 2)
    big = regions.VoronoiRegions(jnp.zeros((5, 2)))
    with pytest.raises(ValueError):
        packed.set(0, big)
    with pytest.raises(ValueError):
        packed.set(0, regions.HalfspaceRegions(w=jnp.zeros(3),
                                               b=jnp.asarray(0.0)))


# ---------------------------------------------------------------------------
# parity: one active query reproduces the single-query simulator
# ---------------------------------------------------------------------------


def test_single_query_parity_with_run_static():
    """The acceptance gate: a Q-slot service with ONE active query matches
    the sim.run_static core loop cycle-for-cycle on full state arrays,
    bitwise on decisions, exactly on message counts."""
    topo = topology.grid(64)
    centers, x = _problem(topo, seed=0)
    ta = lss.TopoArrays.from_topology(topo)
    cfg = lss.LSSConfig()
    inputs = wvs.from_vector(jnp.asarray(x), jnp.ones((topo.n,), jnp.float32))
    core = lss.init_state(ta, inputs, seed=0)

    svc = Service(topo, ServiceConfig(capacity=4, k_max=3, d=2,
                                      cycles_per_dispatch=1))
    qid = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                              inputs=x, seed=0))
    decide = lambda v: regions.decide_voronoi(v, centers)

    quiesced = False
    for _ in range(40):
        core, _ = lss.cycle(core, ta, centers, cfg)
        (rec,) = svc.tick()
        snap = svc.snapshot(qid)
        _assert_state_close(snap, core)
        assert np.array_equal(_decisions(snap, ta, decide),
                              _decisions(core, ta, decide))  # bitwise
        acc_c, q_c, _ = lss.metrics(core, ta, centers)
        assert rec["accuracy"] == float(acc_c)
        assert rec["quiescent"] == bool(q_c)
        quiesced = bool(q_c)
    assert quiesced
    assert svc.total_msgs(qid) == int(core.msgs)


def test_masked_slots_send_zero_messages():
    """Padding queries are true no-ops: zero sends, no pending, untouched
    message buffers — while an active slot works beside them."""
    topo = topology.grid(36)
    centers, x = _problem(topo, seed=3)
    svc = Service(topo, ServiceConfig(capacity=5, k_max=3, d=2,
                                      cycles_per_dispatch=4))
    svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                        inputs=x, seed=0))
    for _ in range(5):
        svc.tick()
        # msgs counters drain every tick; padding slots must never count.
        assert all(int(m) == 0 for m in svc.backend.msgs_of(svc.states)[1:])
    states = svc.states
    assert not bool(jnp.any(states.pending[1:]))
    assert float(jnp.abs(states.out_m[1:]).max()) == 0.0
    assert float(jnp.abs(states.in_m[1:]).max()) == 0.0
    # The active slot did send.
    assert svc.total_msgs("q000000") > 0


def test_batched_queries_match_sequential_runs():
    """Q heterogeneous tenants in one dispatch == Q sequential single-query
    runs (per-query state allclose, decisions bitwise, messages exact)."""
    topo = topology.grid(49)
    q = 6
    svc = Service(topo, ServiceConfig(capacity=q, k_max=4, d=2,
                                      cycles_per_dispatch=7))
    ta = lss.TopoArrays.from_topology(topo)
    tenants = []
    rng = np.random.default_rng(9)
    for i in range(q):
        centers, x = _problem(topo, seed=10 + i)
        if i % 2 == 0:
            fam = regions.VoronoiRegions(centers)
            decide = lambda v, c=centers: regions.decide_voronoi(v, c)
        else:
            w = jnp.asarray(rng.normal(size=2).astype(np.float32))
            fam = regions.HalfspaceRegions(w=w, b=jnp.float32(0.1))
            decide = lambda v, f=fam: f.decide(v)
        beta = 1e-3 if i % 3 else 2e-3
        spec = QuerySpec(region=fam, inputs=x, seed=i, beta=beta,
                         ell=1 + i % 2)
        qid = svc.admit(spec)
        tenants.append((qid, spec, decide, centers))

    svc.serve(4)  # 28 cycles, 4 dispatches

    for qid, spec, decide, centers in tenants:
        cfg = lss.LSSConfig(beta=spec.beta, ell=spec.ell)
        st = lss.init_state(ta, spec.input_wv(), seed=spec.seed)
        for _ in range(28):
            st, _ = lss.cycle(st, ta, centers, cfg, decide=decide)
        snap = svc.snapshot(qid)
        _assert_state_close(snap, st, atol=1e-5)
        assert np.array_equal(_decisions(snap, ta, decide),
                              _decisions(st, ta, decide)), qid
        assert svc.total_msgs(qid) == int(st.msgs), qid


def test_engine_backend_parity():
    """backend='engine' composes the query axis with the shard axis and
    still reproduces the core loop exactly."""
    topo = topology.grid(36)
    centers, x = _problem(topo, seed=5)
    ta = lss.TopoArrays.from_topology(topo)
    svc = Service(topo, ServiceConfig(capacity=3, k_max=3, d=2,
                                      cycles_per_dispatch=5,
                                      backend="engine", engine_shards=2))
    qid = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                              inputs=x, seed=0))
    inputs = wvs.from_vector(jnp.asarray(x), jnp.ones((topo.n,), jnp.float32))
    core = lss.init_state(ta, inputs, seed=0)
    cfg = lss.LSSConfig()
    for _ in range(20):
        core, _ = lss.cycle(core, ta, centers, cfg)
    svc.serve(4)
    _assert_state_close(svc.snapshot(qid), core)
    assert svc.total_msgs(qid) == int(core.msgs)


# ---------------------------------------------------------------------------
# admission lifecycle
# ---------------------------------------------------------------------------


def test_admission_lifecycle_and_no_recompile():
    topo = topology.grid(25)
    centers, x = _problem(topo, seed=1)
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2,
                                      cycles_per_dispatch=2,
                                      admission_queue=0))  # fail fast
    spec = QuerySpec(region=regions.VoronoiRegions(centers), inputs=x)
    a = svc.admit(spec)
    b = svc.admit(QuerySpec(region=regions.HalfspaceRegions(
        w=jnp.asarray([1.0, 0.0]), b=jnp.asarray(0.0)), inputs=x))
    with pytest.raises(RuntimeError):
        svc.admit(spec)  # full, and queueing disabled
    svc.tick()
    compiles_after_warm = jit_cache_size(svc._step)

    svc.retire(a)
    assert svc.registry.num_active == 1
    # Retired slot's state is wiped back to a quiescent padding slot.
    slot_msgs = svc.backend.msgs_of(svc.states)
    assert int(slot_msgs[svc.registry.slot_of(b)]) >= 0  # b's slot intact
    c = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                            inputs=x, seed=4))
    assert svc.registry.slot_of(c) == 0  # reused slot
    svc.replace(b, QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=9))
    assert svc.snapshot(b).t == 0  # replace resets the slot's timeline
    svc.tick()
    if compiles_after_warm is not None:
        # Admission churn must not have recompiled the batched step.
        assert jit_cache_size(svc._step) == compiles_after_warm
    # Unknown ids are rejected.
    with pytest.raises(KeyError):
        svc.retire("nope")


def test_admission_rejects_bad_shapes():
    topo = topology.grid(25)
    centers, x = _problem(topo, seed=1)
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2))
    with pytest.raises(ValueError):
        svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                            inputs=x[:10]))  # wrong peer count
    with pytest.raises(ValueError):
        svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                            inputs=np.zeros((topo.n, 5), np.float32)))


# ---------------------------------------------------------------------------
# streaming ingest
# ---------------------------------------------------------------------------


def test_ingest_set_and_delta_modes():
    topo = topology.grid(25)
    centers, x = _problem(topo, seed=2)
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2,
                                      cycles_per_dispatch=1))
    qa = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=0))
    svc.push_updates([0, 3], [[2.0, 2.0], [4.0, 4.0]], mode="set")
    svc.tick()
    snap = svc.snapshot(qa)
    np.testing.assert_allclose(np.asarray(snap.x_m)[[0, 3]],
                               [[2, 2], [4, 4]])
    np.testing.assert_allclose(np.asarray(snap.x_c)[[0, 3]], [1, 1])
    svc.push_updates([0], [[1.0, -1.0]], mode="delta")
    svc.tick()
    snap = svc.snapshot(qa)
    np.testing.assert_allclose(np.asarray(snap.x_m)[0], [3, 1])


def test_ingest_targets_specific_queries():
    topo = topology.grid(25)
    centers, x = _problem(topo, seed=2)
    svc = Service(topo, ServiceConfig(capacity=3, k_max=3, d=2,
                                      cycles_per_dispatch=1))
    qa = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=0))
    qb = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=1))
    svc.push_updates([7], [[5.0, 5.0]], mode="set", query_ids=[qb])
    svc.tick()
    np.testing.assert_allclose(np.asarray(svc.snapshot(qa).x_m)[7], x[7])
    np.testing.assert_allclose(np.asarray(svc.snapshot(qb).x_m)[7], [5, 5])


def test_ingest_skips_queries_retired_while_queued():
    """A batch targeting a query retired before the next dispatch is
    dropped (not crashed on, and never applied to the slot's new tenant);
    later queued batches still apply."""
    topo = topology.grid(25)
    centers, x = _problem(topo, seed=2)
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2,
                                      cycles_per_dispatch=1))
    qa = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=0))
    qb = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=1))
    svc.push_updates([4], [[7.0, 7.0]], mode="set", query_ids=[qb])
    svc.push_updates([5], [[8.0, 8.0]], mode="set", query_ids=[qa])
    svc.retire(qb)
    qc = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=2))  # reuses qb's slot
    svc.tick()
    np.testing.assert_allclose(np.asarray(svc.snapshot(qa).x_m)[5], [8, 8])
    np.testing.assert_allclose(np.asarray(svc.snapshot(qc).x_m)[4], x[4])


def test_ingest_empty_query_ids_targets_nothing():
    """query_ids=[] means 'no tenants', not 'all tenants'."""
    topo = topology.grid(25)
    centers, x = _problem(topo, seed=2)
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2,
                                      cycles_per_dispatch=1))
    qa = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=0))
    svc.push_updates([3], [[9.0, 9.0]], mode="set", query_ids=[])
    svc.tick()
    np.testing.assert_allclose(np.asarray(svc.snapshot(qa).x_m)[3], x[3])


def test_ingest_queue_bounds_and_validation():
    ing = StreamIngest(max_pending=2)
    ing.push([0], [[1.0, 1.0]])
    ing.push([1], [[1.0, 1.0]])
    with pytest.raises(RuntimeError):
        ing.push([2], [[1.0, 1.0]])
    assert len(ing.drain()) == 2 and len(ing) == 0
    with pytest.raises(ValueError):
        ing.push([0], [[1.0, 1.0]], mode="merge")


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_telemetry_jsonl_roundtrip(tmp_path):
    topo = topology.grid(25)
    centers, x = _problem(topo, seed=4)
    path = tmp_path / "telemetry.jsonl"
    sink = TelemetrySink(path=str(path))
    svc = Service(topo, ServiceConfig(capacity=2, k_max=3, d=2,
                                      cycles_per_dispatch=3),
                  telemetry=sink)
    qa = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                             inputs=x, seed=0))
    svc.serve(3)
    sink.close()
    all_lines = [json.loads(line) for line in path.read_text().splitlines()]
    # The stream also carries kind="span" records (causal trace trees);
    # the per-query telemetry is the subset with a "query" key.
    lines = [r for r in all_lines if "query" in r]
    assert len(lines) == 3  # one active query x three dispatches
    assert any(r.get("kind") == "span" for r in all_lines)
    for i, rec in enumerate(lines):
        assert rec["query"] == qa and rec["dispatch"] == i + 1
        assert rec["t"] == (i + 1) * 3
        assert 0.0 <= rec["accuracy"] <= 1.0
        assert rec["msgs"] >= 0 and "msgs_per_link" in rec
    assert sink.for_query(qa)[-1]["t"] == 9


# ---------------------------------------------------------------------------
# knob-batched config sweeps (the query axis applied to experiments)
# ---------------------------------------------------------------------------


def test_sweep_configs_knob_batch_matches_sequential():
    topo = topology.grid(36)
    spec = sim.ProblemSpec(n=36, seed=3)
    seeds = [0, 1]
    cfgs = [lss.LSSConfig(), lss.LSSConfig(beta=4e-3, ell=2),
            lss.LSSConfig(policy="uniform")]
    res = sweep_configs(topo, spec, seeds, cfgs, cycles=40)
    assert set(res) == {"cfg0", "cfg1", "cfg2"}
    for i, cfg in enumerate(cfgs):
        ref = sweep_static(topo, spec, seeds, cfg, cycles=40)
        got = res[f"cfg{i}"]
        np.testing.assert_allclose(got["accuracy"], ref["accuracy"])
        assert np.array_equal(got["quiescent"], ref["quiescent"]), i
        assert np.array_equal(got["msgs"], ref["msgs"]), i
