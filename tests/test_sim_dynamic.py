"""run_dynamic bookkeeping: churn monotonicity + message accounting.

Covers the noise/churn driver in :mod:`repro.core.sim` that the
figure-6/7/8 benchmarks rely on but the convergence tests only exercised
indirectly.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import lss, sim, topology, wvs


def test_msgs_counter_is_exact_integer():
    """The cumulative send counter must be an integer dtype (float32 loses
    exact counts past 2^24 — million-peer territory)."""
    topo = topology.grid(16)
    ta = lss.TopoArrays.from_topology(topo)
    inputs = wvs.from_vector(jnp.zeros((16, 2)), jnp.ones((16,)))
    state = lss.init_state(ta, inputs)
    assert jnp.issubdtype(state.msgs.dtype, jnp.integer)
    assert state.msgs.dtype == lss.counter_dtype()


def test_alive_mask_monotone_under_churn():
    """cycle() never resurrects peers; churn only shrinks the population."""
    topo = topology.grid(49)
    spec = sim.ProblemSpec(n=49, seed=3)
    centers, _, _, inputs = sim._setup(topo, spec)
    ta, state = sim._core_state(topo, inputs, spec.seed)
    rng = np.random.default_rng(0)
    prev_alive = np.asarray(state.alive).copy()
    for t in range(30):
        if t % 5 == 0:
            dead = rng.choice(49, size=2, replace=False)
            alive = np.asarray(state.alive).copy()
            alive[dead] = False
            state = state._replace(alive=jnp.asarray(alive))
        state, _ = lss.cycle(state, ta, centers, lss.LSSConfig())
        now = np.asarray(state.alive)
        assert not np.any(now & ~prev_alive)  # no resurrection
        prev_alive = now
    assert prev_alive.sum() < 49


def test_run_dynamic_msgs_accounting_consistent():
    """Per-cycle load samples must sum to the total counter delta/edges."""
    topo = topology.grid(49)
    spec = sim.ProblemSpec(n=49, seed=1)
    cfg = lss.LSSConfig()
    warmup, cycles = 0, 60
    res = sim.run_dynamic(topo, spec, cfg, cycles=cycles, warmup=warmup)
    # Replay the identical run and accumulate msgs directly.
    centers, _, _, inputs = sim._setup(topo, spec)
    ta, state = sim._core_state(topo, inputs, spec.seed)
    for _ in range(cycles):
        state, _ = lss.cycle(state, ta, centers, cfg)
    total_per_link = float(state.msgs) / topo.num_edges
    assert np.isclose(res["msgs_per_link_per_cycle"] * cycles,
                      total_per_link)
    assert res["alive_frac"] == 1.0


def test_run_dynamic_warmup_excludes_samples():
    topo = topology.grid(36)
    spec = sim.ProblemSpec(n=36, seed=2)
    res = sim.run_dynamic(topo, spec, cycles=10, warmup=10)
    assert np.isnan(res["avg_accuracy"])
    assert res["msgs_per_link_per_cycle"] == 0.0


def test_run_dynamic_churn_kills_permanently():
    topo = topology.grid(64)
    spec = sim.ProblemSpec(n=64, k=3, d=2, bias=0.2, std=1.0, seed=6)
    res = sim.run_dynamic(topo, spec, lss.LSSConfig(), cycles=200,
                          churn_ppmc=800.0, warmup=50)
    assert 0.0 < res["alive_frac"] < 1.0
    assert res["avg_accuracy"] > 0.5
