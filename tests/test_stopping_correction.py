"""Unit tests for the stopping rule (Def. 4) and balance correction (Sec. IV)."""

import jax.numpy as jnp
import numpy as np

try:  # real hypothesis when installed (CI); seeded fallback shim otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import correction, regions, stopping, wvs


def random_state(rng, n=40, D=4, d=2, zero_frac=0.2):
    x_m = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    x_c = jnp.ones((n,), jnp.float32)
    out_m = jnp.asarray(rng.normal(size=(n, D, d)).astype(np.float32)) * 0.2
    out_c = jnp.asarray(rng.uniform(0.05, 1.0, size=(n, D)).astype(np.float32))
    in_m = jnp.asarray(rng.normal(size=(n, D, d)).astype(np.float32)) * 0.2
    in_c = jnp.asarray(rng.uniform(0.05, 1.0, size=(n, D)).astype(np.float32))
    zero = rng.random((n, D)) < zero_frac
    out_c = jnp.where(zero, 0.0, out_c)
    out_m = jnp.where(zero[..., None], 0.0, out_m)
    in_c = jnp.where(zero, 0.0, in_c)
    in_m = jnp.where(zero[..., None], 0.0, in_m)
    mask = jnp.asarray(rng.random((n, D)) > 0.25)
    return x_m, x_c, out_m, out_c, in_m, in_c, mask


def test_status_definition():
    """S_i = X_ii (+) sum over live slots of (X_ji (-) X_ij)."""
    rng = np.random.default_rng(0)
    x_m, x_c, out_m, out_c, in_m, in_c, mask = random_state(rng)
    s = stopping.status(x_m, x_c, out_m, out_c, in_m, in_c, mask)
    n, D, d = out_m.shape
    for i in range(0, n, 7):
        m = np.asarray(x_m[i]).copy()
        c = float(x_c[i])
        for k in range(D):
            if mask[i, k]:
                m += np.asarray(in_m[i, k] - out_m[i, k])
                c += float(in_c[i, k] - out_c[i, k])
        assert np.allclose(s.m[i], m, atol=1e-5)
        assert np.isclose(s.c[i], c, atol=1e-6)


def test_correction_satisfies_eq1():
    """After Eq.-10 correction, vec(A'_ij) == vec(S'_i) on the violating set
    and |S'_i| == (|S_i| + beta) / 2."""
    rng = np.random.default_rng(1)
    beta = 1e-3
    x_m, x_c, out_m, out_c, in_m, in_c, mask = random_state(rng, zero_frac=0.0)
    s = stopping.status(x_m, x_c, out_m, out_c, in_m, in_c, mask)
    a = stopping.agreements(out_m, out_c, in_m, in_c)
    v = np.asarray(mask)  # correct every live slot (uniform policy)
    new_m, new_c = correction.corrected_messages(s, a, in_m, in_c,
                                                 jnp.asarray(v), beta)
    out_m2 = jnp.where(jnp.asarray(v)[..., None], new_m, out_m)
    out_c2 = jnp.where(jnp.asarray(v), new_c, out_c)
    s2 = stopping.status(x_m, x_c, out_m2, out_c2, in_m, in_c, mask)
    a2 = stopping.agreements(out_m2, out_c2, in_m, in_c)

    va = wvs.vec(a2)
    vs = wvs.vec(s2)
    for i in range(s2.m.shape[0]):
        if not v[i].any():
            continue
        # |S'| = (|S| + beta)/2
        assert np.isclose(float(s2.c[i]), (float(s.c[i]) + beta) / 2,
                          rtol=1e-5), i
        for k in range(v.shape[1]):
            if v[i, k]:
                assert np.allclose(va[i, k], vs[i], atol=1e-4), (i, k)


def test_selective_target_equals_thm8_full_target():
    """S (+) sum_k A_ik == X_ii (+) sum_k 2 (.) X_ki (Thm. 8 vs Eq. 8)."""
    rng = np.random.default_rng(2)
    x_m, x_c, out_m, out_c, in_m, in_c, mask = random_state(rng, zero_frac=0.0)
    mask = jnp.ones_like(mask)
    s = stopping.status(x_m, x_c, out_m, out_c, in_m, in_c, mask)
    a = stopping.agreements(out_m, out_c, in_m, in_c)
    t = correction.selective_target(s, a, mask)
    # Thm. 8 target: X_ii (+) (+)_k 2 (.) X_ki
    t2_m = x_m + jnp.sum(2.0 * in_m, axis=1)
    t2_c = x_c + jnp.sum(2.0 * in_c, axis=1)
    assert np.allclose(t.m, t2_m, atol=1e-5)
    assert np.allclose(t.c, t2_c, atol=1e-5)


def test_def4_on_balanced_state():
    """A state where all A_ij and S-A_ij share S's region satisfies Def. 4."""
    centers = jnp.array([[0.0, 0.0], [10.0, 10.0]])
    decide = lambda v: regions.decide_voronoi(v, centers)
    n, D, d = 8, 3, 2
    # Everyone balanced at vector (1,1) (region 0), equal weights.
    vec_ref = jnp.ones((d,)) * 1.0
    out_m = jnp.broadcast_to(vec_ref * 0.25, (n, D, d))
    out_c = jnp.full((n, D), 0.25)
    in_m = jnp.broadcast_to(vec_ref * 0.25, (n, D, d))
    in_c = jnp.full((n, D), 0.25)
    x_m = jnp.broadcast_to(vec_ref, (n, d))
    x_c = jnp.ones((n,))
    mask = jnp.ones((n, D), bool)
    s = stopping.status(x_m, x_c, out_m, out_c, in_m, in_c, mask)
    a = stopping.agreements(out_m, out_c, in_m, in_c)
    ok = stopping.def4_satisfied(decide, s, a, mask)
    assert bool(jnp.all(ok))
    viol = stopping.violations_alg1(decide, s, a, mask)
    assert not bool(jnp.any(viol))


def test_zero_weight_agreement_violates_alg1():
    """Alg.-1 set treats never-communicated links as violating (bootstrap)."""
    centers = jnp.array([[0.0, 0.0], [10.0, 10.0]])
    decide = lambda v: regions.decide_voronoi(v, centers)
    n, D, d = 4, 2, 2
    zeros = jnp.zeros((n, D, d))
    s = wvs.WV(jnp.ones((n, d)), jnp.ones((n,)))
    a = wvs.WV(zeros, jnp.zeros((n, D)))
    mask = jnp.ones((n, D), bool)
    viol = stopping.violations_alg1(decide, s, a, mask)
    assert bool(jnp.all(viol))
    # ... but Def. 4 itself is satisfied (zero-weight guard) — the
    # bootstrap clause is deliberately stronger; see stopping.py docstring.
    ok = stopping.def4_satisfied(decide, s, a, mask)
    assert bool(jnp.all(ok))
