"""Substrate tests: checkpoint, data, optimizer, compression, trainer FT."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data import TokenSource
from repro.distributed import compression
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    clip_by_global_norm, cosine_schedule


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    checkpoint.save(tmp_path, 7, t)
    assert checkpoint.latest_step(tmp_path) == 7
    t2 = checkpoint.load(tmp_path, 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        checkpoint.save_async(tmp_path, s, t, max_keep=2)
    checkpoint.wait_pending()
    assert checkpoint.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in tmp_path.glob("step_*") if p.is_dir())
    assert len(kept) <= 2
    t2 = checkpoint.load(tmp_path, 5, t)
    np.testing.assert_array_equal(np.asarray(t2["a"]), np.asarray(t["a"]))


def test_checkpoint_atomic_no_partial(tmp_path):
    """A tmp dir left behind must never be visible as a checkpoint."""
    t = _tree()
    checkpoint.save(tmp_path, 1, t)
    (tmp_path / "step_00000002.tmp").mkdir()
    assert checkpoint.latest_step(tmp_path) == 1


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with explicit shardings (mesh-to-mesh move)."""
    t = _tree()
    checkpoint.save(tmp_path, 3, t)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    t2 = checkpoint.load(tmp_path, 3, t, shardings=sh)
    assert t2["a"].sharding.mesh.shape == {"data": 1}


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_sharded():
    src = TokenSource(vocab=1000, seq_len=16, global_batch=8, seed=3)
    b1 = src.global_batch_at(5)
    b2 = src.global_batch_at(5)
    np.testing.assert_array_equal(np.asarray(b1.tokens), np.asarray(b2.tokens))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1.tokens[:, 1:]),
                                  np.asarray(b1.labels[:, :-1]))
    # different steps differ
    b3 = src.global_batch_at(6)
    assert not np.array_equal(np.asarray(b1.tokens), np.asarray(b3.tokens))


def test_data_vocab_range():
    src = TokenSource(vocab=50, seq_len=64, global_batch=4)
    b = src.global_batch_at(0)
    assert int(b.tokens.min()) >= 0 and int(b.tokens.max()) < 50


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0)

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - jnp.array([1.0, 2.0, 3.0])))

    for _ in range(300):
        g = jax.grad(loss)(params)
        _, g = clip_by_global_norm(g, 10.0)
        params, opt = adamw_update(params, g, opt, 0.05, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               [1.0, 2.0, 3.0], atol=0.05)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    norm, g2 = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g2)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    import numpy as np
    lrs = [float(cosine_schedule(jnp.asarray(s), 1e-3, 10, 100))
           for s in range(0, 100, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=0.1)
    assert lrs[-1] < lrs[4]


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_int8_error_feedback_preserves_sum():
    """With error feedback, quantization error does not accumulate: the
    running sum of decompressed values tracks the true running sum."""
    rng = np.random.default_rng(0)
    err = None
    true_sum = np.zeros(64, np.float32)
    deq_sum = np.zeros(64, np.float32)
    for _ in range(100):
        g = jnp.asarray(rng.normal(size=64).astype(np.float32))
        pack, err = compression.int8_compress(g, err)
        deq = compression.int8_decompress(pack)
        true_sum += np.asarray(g)
        deq_sum += np.asarray(deq)
    # residual error is bounded by one quantization step, not ~100 steps
    assert np.max(np.abs(true_sum - deq_sum)) < 0.5


def test_topk_error_feedback():
    rng = np.random.default_rng(1)
    err = None
    true_sum = np.zeros(128, np.float32)
    sent_sum = np.zeros(128, np.float32)
    for _ in range(200):
        g = jnp.asarray(rng.normal(size=128).astype(np.float32))
        kept, err = compression.topk_compress(g, err, frac=0.1)
        true_sum += np.asarray(g)
        sent_sum += np.asarray(kept)
    # every coordinate eventually ships (error feedback) — relative error
    # of the running sum stays small
    denom = np.maximum(np.abs(true_sum), 1.0)
    assert np.median(np.abs(true_sum - sent_sum) / denom) < 0.6


def test_topk_keeps_top_fraction():
    x = jnp.arange(100.0)
    kept, err = compression.topk_compress(x, None, frac=0.1)
    assert int(jnp.sum(kept != 0)) == 10
    assert float(kept[99]) == 99.0 and float(kept[0]) == 0.0


# ---------------------------------------------------------------------------
# trainer fault tolerance
# ---------------------------------------------------------------------------


def test_trainer_resume_and_fault_recovery(tmp_path):
    from repro.training.trainer import Trainer, TrainerConfig

    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)

    def step_fn(p, o, batch):
        g = {"w": p["w"] - batch}
        _, g = clip_by_global_norm(g, 1e9)
        p2, o2 = adamw_update(p, g, o, 0.1, AdamWConfig(weight_decay=0.0))
        return p2, o2, {"loss": jnp.sum(jnp.square(p2["w"] - batch))}

    def batch_fn(step):
        return jnp.full((4,), 1.0)

    cfg = TrainerConfig(total_steps=30, ckpt_every=10,
                        ckpt_dir=str(tmp_path), log_every=5)
    boom = {"armed": True}

    def fault(step):
        if step == 17 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected device failure")

    tr = Trainer(cfg, step_fn, batch_fn)
    params2, opt2 = tr.run(params, opt, fault_injector=fault)
    events = [m.get("event") for m in tr.metrics_log]
    assert "restored" in events  # failure was recovered from a checkpoint
    assert int(opt2.step) >= 30 - 10  # made it to the end after restore
    assert checkpoint.latest_step(tmp_path) == 30
