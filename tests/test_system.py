"""End-to-end behaviour tests for the paper's system.

The core claim chain, executed as one story:
  1. peers on a *cyclic* network compute a thresholded function of the
     global average with purely local traffic (the paper);
  2. a small LM actually trains with the full production step (loss drops);
  3. checkpoint/resume is bit-exact (fault-tolerance substrate).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as cfgs
from repro.configs import ShapeCell
from repro.core import lss, topology, wvs
from repro.data import TokenSource
from repro.models import build
from repro.optim import adamw_init
from repro.training.steps import TrainHParams, build_for_cell


def test_paper_end_to_end_majority_vote():
    """Majority vote (footnote 3: C = {0,1}) on a cyclic graph."""
    n = 49
    topo = topology.grid(n)
    ta = lss.TopoArrays.from_topology(topo)
    centers = jnp.array([[0.0], [1.0]])
    rng = np.random.default_rng(0)
    votes = (rng.random(n) < 0.62).astype(np.float32)[:, None]
    st = lss.init_state(ta, wvs.from_vector(jnp.asarray(votes),
                                            jnp.ones((n,))))
    cfg = lss.LSSConfig()
    for _ in range(150):
        st, _ = lss.cycle(st, ta, centers, cfg)
    acc, quiescent, _ = lss.metrics(st, ta, centers)
    assert bool(quiescent)
    assert float(acc) == 1.0  # every peer knows the majority is "1"


def test_lm_training_loss_decreases():
    """Small LM, 30 real optimizer steps through the production train step:
    loss must drop."""
    cfg = cfgs.get_smoke("yi-9b")
    model = build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cell = ShapeCell("t", "train", 64, 8)
    src = TokenSource(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
    with mesh:
        step, _, _, _ = build_for_cell(
            model, mesh, cell, TrainHParams(lr=3e-3, warmup=5,
                                            total_steps=100))
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        losses = []
        for s in range(30):
            b = src.global_batch_at(s)
            params, opt, m = step(params, opt,
                                  {"tokens": b.tokens, "labels": b.labels})
            losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_checkpoint_resume_is_exact(tmp_path):
    """Stop at step 10, resume from disk, land bit-identically at step 12."""
    from repro import checkpoint

    cfg = cfgs.get_smoke("mamba2-370m")
    model = build(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    cell = ShapeCell("t", "train", 32, 4)
    src = TokenSource(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=1)
    with mesh:
        step, _, _, _ = build_for_cell(model, mesh, cell, TrainHParams())
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        for s in range(10):
            b = src.global_batch_at(s)
            params, opt, _ = step(params, opt,
                                  {"tokens": b.tokens, "labels": b.labels})
        checkpoint.save(tmp_path, 10, (params, opt))
        p_ref, o_ref = params, opt
        for s in (10, 11):
            b = src.global_batch_at(s)
            p_ref, o_ref, _ = step(p_ref, o_ref,
                                   {"tokens": b.tokens, "labels": b.labels})
        p2, o2 = checkpoint.load(tmp_path, 10, (params, opt))
        for s in (10, 11):
            b = src.global_batch_at(s)
            p2, o2, _ = step(p2, o2,
                             {"tokens": b.tokens, "labels": b.labels})
    for a, b_ in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
