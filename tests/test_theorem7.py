"""Thm. 7: a termination state exists for any connected graph + inputs.

We build the constructive assignment from the proof — spanning-tree
messages X_ij = 1/2 (.) Y_i  (-)  1/(4|V|) (.) (+)X and
X_ji = 3/(4|V|) (.) (+)X (-) 1/2 (.) Y_i, zero-weight off-tree links —
and check the proof's invariants numerically on a *cyclic* graph:

  * every tree-edge difference X_ij (-) X_ji has zero weight, hence the
    subtree status Y_i has weight exactly 1 for every node;
  * all A_ij and S_i (-) A_ij equal (1/(2|V|)) (.) (+)X (vector = global
    mean, weight 1/2);
  * Def. 4 holds at every peer for any region family containing the mean.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import stopping, topology, wvs


def _bfs_tree(topo: topology.Topology):
    import collections

    n = topo.n
    parent = np.full(n, -2, np.int64)
    parent[0] = -1
    q = collections.deque([0])
    adj = [
        [int(topo.nbr[i, k]) for k in range(topo.max_deg) if topo.mask[i, k]]
        for i in range(n)
    ]
    order = [0]
    while q:
        u = q.popleft()
        for v in adj[u]:
            if parent[v] == -2:
                parent[v] = u
                order.append(v)
                q.append(v)
    assert (parent != -2).all(), "graph not connected"
    return parent, order


def test_thm7_construction_is_stopping_state():
    topo = topology.grid(25)  # cyclic!
    n, D = topo.n, topo.max_deg
    d = 2
    rng = np.random.default_rng(0)
    xv = rng.normal(size=(n, d)).astype(np.float64)
    gx_m = xv.sum(0)  # moment of (+)X (weight n)
    gx_mean = gx_m / n
    parent, order = _bfs_tree(topo)

    out_m = np.zeros((n, D, d))
    out_c = np.zeros((n, D))
    in_m = np.zeros((n, D, d))
    in_c = np.zeros((n, D))

    def slot(i, j):
        for k in range(D):
            if topo.mask[i, k] and topo.nbr[i, k] == j:
                return k
        raise KeyError((i, j))

    # Bottom-up: Y_i = X_ii (+) sum over children (X_ki (-) X_ik), then the
    # proof's messages for the edge to the parent.  The child differences
    # carry ZERO weight (each is (+)_{V_k} X (-) (|V_k|/|V|)(.)( +)X), so
    # |Y_i| == 1 for every node — the subtlety the proof's induction rests
    # on.
    y_m = xv.copy()
    y_c = np.ones(n)
    for u in reversed(order):
        p = parent[u]
        if p < 0:
            continue
        # messages on edge (u -> p) from Y_u
        m_up = 0.5 * y_m[u] - gx_m / (4.0 * n) * 1.0  # 1/(4|V|) (.) (+)X
        c_up = 0.5 * y_c[u] - 0.25
        m_dn = 3.0 * gx_m / (4.0 * n) - 0.5 * y_m[u]
        c_dn = 0.75 - 0.5 * y_c[u]
        ku, kp = slot(u, p), slot(p, u)
        out_m[u, ku], out_c[u, ku] = m_up, c_up
        in_m[p, kp], in_c[p, kp] = m_up, c_up
        out_m[p, kp], out_c[p, kp] = m_dn, c_dn
        in_m[u, ku], in_c[u, ku] = m_dn, c_dn
        # fold this edge into the parent's Y (children-only status)
        y_m[p] += m_up - m_dn
        y_c[p] += c_up - c_dn

    # Invariant: |Y_i| == 1 everywhere (zero-weight differences).
    assert np.allclose(y_c, 1.0, atol=1e-12)

    mask = jnp.asarray(topo.mask)
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    s = stopping.status(f32(xv), jnp.ones((n,)), f32(out_m), f32(out_c),
                        f32(in_m), f32(in_c), mask)
    a = stopping.agreements(f32(out_m), f32(out_c), f32(in_m), f32(in_c))

    # S_i: weight 1, vector = global mean, for every peer.
    assert np.allclose(np.asarray(s.c), 1.0, atol=1e-5)
    assert np.allclose(np.asarray(wvs.vec(s)), gx_mean, atol=1e-4)

    # Tree-edge agreements: weight 1/2, vector = global mean; off-tree
    # edges zero-weight.
    ac = np.asarray(a.c)
    va = np.asarray(wvs.vec(a))
    for i in range(n):
        for k in range(D):
            if not topo.mask[i, k]:
                continue
            if abs(ac[i, k]) < 1e-9:
                continue  # off-tree: zero weight, Def.-4 guard applies
            assert np.isclose(ac[i, k], 0.5, atol=1e-5), (i, k)
            assert np.allclose(va[i, k], gx_mean, atol=1e-4), (i, k)

    # Def. 4 holds in the context of any region family containing the mean.
    centers = jnp.asarray(
        np.stack([gx_mean + 0.01, gx_mean + 5.0]).astype(np.float32))
    from repro.core import regions
    decide = lambda v: regions.decide_voronoi(v, centers)
    ok = stopping.def4_satisfied(decide, s, a, mask)
    assert bool(jnp.all(ok))
