"""Causal tracing, profiling, flight recorder, alerts, push tracker.

The PR-7 contract: every span record carries ``span_id``/``parent_id``/
tenant ``trace`` ids and reassembles into a complete causal forest
(every dispatch reachable from the admission that minted its trace id);
``ProfiledDispatch`` splits host from device wall per dispatch on both
service backends; the flight recorder dumps its ring exactly when an
SLO violation / eviction / epoch / alert happens; alert rules fire on
sustained predicates only; and ALL of it keeps serving bitwise
identical to an uninstrumented run.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import regions, sim, topology
from repro.obs import (AlertEngine, AlertRule, FlightRecorder, InMemoryTracker,
                       MetricsRegistry, NoopTracker, ProfiledDispatch,
                       PushTracker, assemble, render_histogram, trace_view,
                       validate_record, validate_stream)
from repro.service import QuerySpec, Service, ServiceConfig, SLOSpec

ALWAYS = (AlertRule(name="always", metric="service_queue_depth",
                    above=-1.0),)


def _specs(n, q, seed=3):
    centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=n, seed=seed))
    rng = np.random.default_rng(seed + 1)
    return [QuerySpec(region=regions.VoronoiRegions(jnp.asarray(centers)),
                      inputs=sample(rng, n), seed=i) for i in range(q)]


def _serve(tracker=None, ticks=3, n_specs=3, slo=None, **cfg_kw):
    topo = topology.grid(36)
    kw = dict(capacity=3, k_max=3, d=2, cycles_per_dispatch=2)
    kw.update(cfg_kw)
    svc = Service(topo, ServiceConfig(**kw), tracker=tracker)
    for s in _specs(topo.n, n_specs):
        if slo is not None:
            s = dataclasses.replace(s, slo=slo)
        svc.admit(s)
    out = []
    for _ in range(ticks):
        out.extend(svc.tick())
    return svc, out


# ---------------------------------------------------------------------------
# trace trees
# ---------------------------------------------------------------------------


def test_trace_round_trip_every_dispatch_has_admission_ancestor():
    tr = InMemoryTracker()
    svc, _ = _serve(tracker=tr, ticks=3)
    forest = assemble(tr.records)
    assert forest.orphans == []  # stream completeness
    tids = forest.trace_ids()
    assert len(tids) == 3  # one per admitted tenant
    for tid in tids:
        tree = forest.tenant(tid)
        assert len(tree.spans_named("admission")) == 1
        assert len(tree.spans_named("dispatch")) == 3  # one per tick
        assert tree.has_ancestry("dispatch", "admission")
        assert tree.has_ancestry("observe", "admission")
        (root,) = tree.roots  # single tree, rooted at admission
        assert root.name == "admission"
    svc.close()


def test_trace_ids_deterministic_and_in_records():
    """Trace ids are minted service-side (never by the tracker), so the
    per-query record stream is identical across backends."""
    tr = InMemoryTracker()
    svc, _ = _serve(tracker=tr, ticks=1)
    per_q = [r for r in tr.records if "query" in r]
    assert all(r["trace_id"] == f"t{i + 1:05d}:{r['query']}"
               for i, r in enumerate(sorted(per_q, key=lambda r: r["slot"])))
    svc.close()


def test_preempt_resume_spans_carry_tenant_trace():
    tr = InMemoryTracker()
    topo = topology.grid(16)
    svc = Service(topo, ServiceConfig(capacity=1, k_max=3, d=2,
                                      cycles_per_dispatch=2,
                                      admission_queue=4),
                  tracker=tr)
    (a,) = _specs(topo.n, 1)
    qa = svc.admit(a)
    svc.tick()
    svc._preempt(qa)  # scheduler entry point, driven directly
    svc._resume(qa)
    svc.tick()
    forest = assemble(tr.records)
    names = {n.name for n in forest.nodes.values()}
    assert {"admission", "activate", "preempt", "resume"} <= names
    for name in ("preempt", "resume"):
        (node,) = [n for n in forest.nodes.values() if n.name == name]
        assert node.trace and node.trace[0].endswith(qa)
    # The tenant projection keeps the suspension in its causal chain.
    tree = forest.tenant(forest.trace_ids()[0])
    assert tree.has_ancestry("preempt", "admission")
    assert tree.has_ancestry("resume", "admission")
    svc.close()


def test_trace_view_renders_and_epoch_spans_fan_out():
    tr = InMemoryTracker()
    dyn = topology.DynTopology.from_topology(topology.grid(36), n_cap=38,
                                             deg_cap=6)
    svc = Service(dyn, ServiceConfig(capacity=2, k_max=3, d=2,
                                     cycles_per_dispatch=2), tracker=tr)
    for s in _specs(dyn.n, 2):
        svc.admit(s)
    svc.tick()
    svc.grow_capacity(n_cap=44)
    svc.tick()
    forest = assemble(tr.records)
    # The epoch span names every active tenant's trace id.
    (epoch,) = [n for n in forest.nodes.values() if n.name == "epoch_regrow"]
    assert set(epoch.trace) == set(forest.trace_ids())
    view = trace_view(tr.records)
    for tid in forest.trace_ids():
        assert tid in view
    assert "admission" in view and "dispatch" in view
    assert "orphan" not in view
    # Single-tenant render accepts an explicit id, and a forest directly.
    one = trace_view(forest, trace_id=forest.trace_ids()[0])
    assert forest.trace_ids()[1] not in one
    svc.close()


# ---------------------------------------------------------------------------
# device-time attribution
# ---------------------------------------------------------------------------


def test_profiled_dispatch_gauges_on_both_backends():
    for backend in ("core", "engine"):
        tr = InMemoryTracker()
        kw = dict(engine_shards=2) if backend == "engine" else {}
        svc, _ = _serve(tracker=tr, ticks=2, backend=backend,
                        profile_dispatch=True, **kw)
        reg = tr.registry
        for name in ("dispatch_host_ms", "dispatch_device_ms",
                     "host_overhead_frac"):
            val = reg.gauge(name).value(backend=backend)
            assert val is not None and val >= 0.0, (backend, name)
        frac = reg.gauge("host_overhead_frac").value(backend=backend)
        assert 0.0 <= frac <= 1.0
        svc.close()


def test_profiled_dispatch_unit_semantics():
    tr = InMemoryTracker()
    fn = ProfiledDispatch(jax.jit(lambda x: x * 2), tr, backend="unit")
    out = fn(jnp.arange(4.0))
    assert np.array_equal(np.asarray(out), [0.0, 2.0, 4.0, 6.0])
    assert fn.calls == 1
    last = fn.last
    assert last["host_ms"] >= 0 and last["device_ms"] >= 0
    assert tr.registry.gauge("dispatch_host_ms").value(backend="unit") \
        == pytest.approx(last["host_ms"])
    # Publishing goes through log_metrics only: a Noop tracker drops it.
    noop = NoopTracker()
    ProfiledDispatch(lambda x: x, noop, backend="unit")(1)
    assert noop.registry.names() == []


def test_engine_mesh_transport_spans_on_collective_path(subproc):
    """On a real 4-device mesh the dispatch spans say transport=
    all_to_all and the per-shard halo/cut counters are nonzero."""
    out = subproc("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import lss, sim, topology, wvs
from repro.engine import ShardedLSS, EngineConfig
from repro.obs import InMemoryTracker, assemble

topo = topology.grid(64)
centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=64, seed=0))
rng = np.random.default_rng(1)
inputs = wvs.from_vector(jnp.asarray(sample(rng, topo.n)),
                         jnp.ones((topo.n,), jnp.float32))
tr = InMemoryTracker()
mesh = jax.make_mesh((4,), ("shards",))
eng = ShardedLSS(topo, centers, lss.LSSConfig(),
                 EngineConfig(num_shards=4, cycles_per_dispatch=4,
                              profile=True),
                 tracker=tr).use_mesh(mesh, "shards")
est = eng.init(inputs, seed=0)
est = eng.run(est, 8)
spans = [n for n in assemble(tr.records).nodes.values()
         if n.name == "engine.dispatch"]
assert len(spans) == 2
assert all(s.attrs["transport"] == "all_to_all" for s in spans)
assert all(s.attrs["halo_bytes"] > 0 for s in spans)
assert all(s.attrs["cut_edges"] > 0 for s in spans)
halo = tr.registry.get("engine_shard_halo_bytes_total")
for s in range(4):
    assert halo.value(shard=str(s), transport="all_to_all") > 0
    assert tr.registry.gauge("engine_shard_cut_edges").value(
        shard=str(s)) > 0
frac = tr.registry.gauge("host_overhead_frac").value(backend="engine-mesh")
assert frac is not None and 0.0 <= frac <= 1.0
print("MESH_TRANSPORT_SPANS_OK")
""", n_devices=4)
    assert "MESH_TRANSPORT_SPANS_OK" in out


# ---------------------------------------------------------------------------
# alert rules
# ---------------------------------------------------------------------------


def test_alert_rule_sustain_window_semantics():
    reg = MetricsRegistry()
    eng = AlertEngine([AlertRule(name="hot", metric="temp", above=10.0,
                                 sustain=3)], reg)
    g = reg.gauge("temp")
    g.set(50.0)
    assert eng.evaluate() == []  # streak 1
    assert eng.evaluate() == []  # streak 2
    (fired,) = eng.evaluate(dispatch=7)  # streak 3: fires once
    assert fired["state"] == "firing" and fired["value"] == 50.0
    assert fired["dispatch"] == 7 and fired["sustain"] == 3
    assert eng.evaluate() == []  # no re-fire while firing
    g.set(5.0)
    (resolved,) = eng.evaluate()
    assert resolved["state"] == "resolved"
    g.set(50.0)
    assert eng.evaluate() == []  # streak restarts after resolve
    assert validate_record(fired) == [] and validate_record(resolved) == []


def test_alert_blip_below_sustain_never_fires():
    reg = MetricsRegistry()
    eng = AlertEngine([AlertRule(name="hot", metric="temp", above=10.0,
                                 sustain=2)], reg)
    g = reg.gauge("temp")
    for v in (50.0, 5.0, 50.0, 5.0):  # alternating: streak never hits 2
        g.set(v)
        assert eng.evaluate() == []
    assert eng.firing() == []


def test_alert_label_filter_and_series_disappearance():
    reg = MetricsRegistry()
    eng = AlertEngine([AlertRule(name="q-acc", metric="tenant_accuracy",
                                 below=0.5, labels=(("query", "q1"),))], reg)
    g = reg.gauge("tenant_accuracy")
    g.set(0.1, query="q1")
    g.set(0.1, query="q2")  # filtered out
    (fired,) = eng.evaluate()
    assert fired["labels"] == {"query": "q1"}
    reg.remove_labels(query="q1")  # tenant retired: scrubbed
    assert eng.evaluate() == []  # silent resolve, no record
    assert eng.firing() == []


def test_service_alerts_emit_records_into_stream():
    tr = InMemoryTracker()
    svc, _ = _serve(tracker=tr, ticks=2, alerts=ALWAYS)
    alerts = [r for r in tr.records if r.get("kind") == "alert"]
    assert len(alerts) == 1  # fires on the first observe, then holds
    assert alerts[0]["rule"] == "always"
    assert alerts[0]["dispatch"] >= 1
    assert validate_stream(tr.records) == []
    svc.close()


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_flight_recorder_ring_and_manual_dump(tmp_path):
    fr = FlightRecorder(InMemoryTracker(), capacity=3)
    for i in range(5):
        fr.log_record({"kind": "control", "dispatch": i, "t": i,
                       "queue_depth": 0, "preempted_depth": 0})
    assert len(fr) == 3  # bounded ring
    assert [r["dispatch"] for r in fr.snapshot()] == [2, 3, 4]
    assert len(fr.inner.records) == 5  # inner tracker got everything
    path = str(tmp_path / "dump.jsonl")
    fr.dump(path, reason="test", dispatch=5)
    lines = [json.loads(l) for l in open(path)]
    assert lines[0]["kind"] == "flight" and lines[0]["reason"] == "test"
    assert lines[0]["records"] == 3
    assert [r["dispatch"] for r in lines[1:]] == [2, 3, 4]
    assert validate_stream(lines) == []


def test_flight_dump_triggered_by_slo_violation(tmp_path):
    """An impossible SLO (accuracy > 1 required) violates on the first
    observe; the service auto-dumps its ring into flight_dump_dir."""
    svc, _ = _serve(ticks=2, slo=SLOSpec(target_accuracy=1.5),
                    flight_dump_dir=str(tmp_path))
    dumps = sorted(os.listdir(tmp_path))
    assert dumps and all("slo_violation" in d for d in dumps)
    lines = [json.loads(l) for l in open(tmp_path / dumps[0])]
    assert lines[0]["kind"] == "flight"
    assert lines[0]["reason"] == "slo_violation"
    assert any(r.get("slo_ok") is False for r in lines[1:])
    assert any(r.get("kind") == "span" for r in lines[1:])  # spans ride along
    svc.close()


def test_flight_dump_triggered_by_alert_and_manual_api(tmp_path):
    svc, _ = _serve(ticks=1, alerts=ALWAYS, flight_dump_dir=str(tmp_path))
    dumps = os.listdir(tmp_path)
    assert len(dumps) == 1 and "alert" in dumps[0]
    path = svc.dump_flight_recorder(reason="because")
    assert os.path.basename(path).endswith("because.jsonl")
    assert json.loads(open(path).readline())["reason"] == "because"
    os.remove(path)
    svc.close()


def test_flight_dump_on_crash(tmp_path):
    svc, _ = _serve(ticks=1, flight_dump_dir=str(tmp_path))
    svc._step_call = None  # break the dispatch path
    with pytest.raises(TypeError):
        svc.tick()
    assert any("crash" in d for d in os.listdir(tmp_path))
    svc.close()


def test_no_auto_dump_without_dir(tmp_path):
    cwd = os.getcwd()
    os.chdir(tmp_path)
    try:
        svc, _ = _serve(ticks=1, slo=SLOSpec(target_accuracy=1.5))
        assert os.listdir(".") == []  # violation happened, no dump
        svc.close()
    finally:
        os.chdir(cwd)


# ---------------------------------------------------------------------------
# push tracker
# ---------------------------------------------------------------------------


def test_push_tracker_buffers_and_flushes():
    tr = PushTracker(flush_every=3)
    assert tr.log({"a": 1}) == 0
    assert tr.log({"a": 2}) == 1
    assert tr.pushed == []  # below flush_every
    tr.log({"a": 3})
    assert len(tr.pushed) == 1  # auto-flush at 3
    assert [p["step"] for p in tr.pushed[0]] == [0, 1, 2]
    tr.log({"a": 4}, step=10)  # explicit step jumps forward
    with pytest.raises(ValueError):
        tr.log({"a": 5}, step=3)  # monotone: can't go back
    tr.close()  # drains the remainder
    assert tr.pushed[1][0] == {"step": 10, "a": 4}
    # Tracker-protocol entry points produce payloads + registry state.
    tr2 = PushTracker(flush_every=1)
    tr2.log_metrics({"depth": 2.0}, backend="core")
    assert tr2.registry.gauge("depth").value(backend="core") == 2.0
    assert tr2.pushed[0][0]["metrics"] == {"depth": 2.0}


def test_push_tracker_service_parity():
    noop_out, noop_states = None, None
    for tracker in (NoopTracker(), PushTracker(flush_every=4)):
        svc, out = _serve(tracker=tracker, ticks=3)
        states = svc.states
        svc.close()
        if noop_out is None:
            noop_out, noop_states = out, states
        else:
            assert out == noop_out
            for a, b in zip(states, noop_states):
                assert np.array_equal(np.asarray(a), np.asarray(b))
            recs = [p["record"] for batch in tracker.pushed for p in batch
                    if "record" in p]
            assert validate_stream(recs) == []


# ---------------------------------------------------------------------------
# parity: full instrumentation on == off
# ---------------------------------------------------------------------------


def test_full_instrumentation_bitwise_parity(tmp_path):
    """profile_dispatch + alerts + flight auto-dump + tracing all on,
    vs a bare NoopTracker run: records and states bitwise identical."""
    def run(tracker, **cfg_kw):
        svc, out = _serve(tracker=tracker, ticks=4, **cfg_kw)
        states = svc.states
        svc.close()
        return out, states

    rec_off, st_off = run(NoopTracker())
    rec_on, st_on = run(InMemoryTracker(), profile_dispatch=True,
                        alerts=ALWAYS, flight_capacity=64,
                        flight_dump_dir=str(tmp_path))
    assert rec_on == rec_off  # floats exactly equal, trace ids included
    for a, b in zip(st_on, st_off):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_engine_profile_flag_bitwise_parity():
    from repro.core import lss, wvs
    from repro.engine import EngineConfig, ShardedLSS

    topo = topology.grid(25)
    centers, sample, _, _ = sim.make_problem(sim.ProblemSpec(n=25, seed=0))
    rng = np.random.default_rng(0)
    inputs = wvs.from_vector(jnp.asarray(sample(rng, topo.n)),
                             jnp.ones((topo.n,), jnp.float32))
    outs = []
    for profile, tracker in ((False, None), (True, InMemoryTracker())):
        eng = ShardedLSS(topo, centers, lss.LSSConfig(),
                         EngineConfig(num_shards=2, cycles_per_dispatch=4,
                                      profile=profile), tracker=tracker)
        outs.append(eng.run(eng.init(inputs, seed=0), 8))
    for a, b in zip(*outs):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# schema + dashboard hardening
# ---------------------------------------------------------------------------


def test_new_record_kinds_validate():
    span = {"kind": "span", "name": "dispatch", "span_id": 3, "seconds": 0.1,
            "parent_id": 1, "trace": ["t00001:q0"], "attrs": {"k": 2}}
    alert = {"kind": "alert", "rule": "r", "metric": "m", "value": 1.0,
             "state": "firing", "dispatch": 0, "t": 0, "sustain": 2,
             "labels": {}}
    flight = {"kind": "flight", "reason": "crash", "records": 9,
              "error": "boom"}
    assert validate_stream([span, alert, flight]) == []
    assert validate_record({**span, "span_id": "three"})  # wrong type
    assert validate_record({"kind": "span", "name": "x"})  # missing fields


def test_dashboard_histogram_and_empty_series():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    assert "no samples" in render_histogram(h)
    for v in (0.05, 0.5, 0.5, 5.0):
        h.observe(v, span="tick")
    art = render_histogram(h, span="tick")
    assert "lat" in art and "█" in art
    # trace_view over a stream with no spans degrades, never raises.
    assert "no tenant spans" in trace_view([{"query": "q0"}])
