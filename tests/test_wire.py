"""Halo wire formats: lossless round-trips, quantization bounds,
engine/service parity, lossy-wire convergence, and plan autotuning.

The contract under test is ISSUE 10's tentpole: ``wire="compact"`` is
bitwise-invisible everywhere (values AND the drop-RNG stream), the
quantized wires honor their documented per-component error bound and
still reach the paper's decisions (the algorithm is self-stabilizing
under message perturbation — the property that makes lossy transport
safe), and ``EngineConfig(auto_plan=True)`` adopts a plan whose measured
dispatch wall is within 10% of the best enumerated candidate.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lss, sim, topology, wvs
from repro.distributed.compression import quantize_halo
from repro.engine import EngineConfig, ShardedLSS
from repro.engine import autotune, exchange

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # hermetic container: seeded fallback shim
    from _hypothesis_fallback import given, settings, strategies as st


def _rand_halo(seed, S=3, H=11, d=2, ragged=True):
    """Random (S, S, H[, d]) halo buffers + flags; ``ragged`` zeroes each
    pair's flags past its own random width (per-pair occupied widths)."""
    rng = np.random.default_rng(seed)
    buf_m = rng.normal(size=(S, S, H, d)).astype(np.float32) * 10
    buf_c = rng.normal(size=(S, S, H)).astype(np.float32)
    flag = rng.random((S, S, H)) < 0.6
    if ragged:
        widths = rng.integers(0, H + 1, size=(S, S))
        flag &= np.arange(H)[None, None, :] < widths[:, :, None]
    return jnp.asarray(buf_m), jnp.asarray(buf_c), jnp.asarray(flag)


# ---------------------------------------------------------------------------
# lossless round-trips (property tests)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**16), st.integers(1, 40))
def test_pack_unpack_bits_roundtrip(seed, width):
    rng = np.random.default_rng(seed)
    flag = jnp.asarray(rng.random((3, 3, width)) < 0.5)
    packed = exchange.pack_bits(flag)
    assert packed.dtype == jnp.uint8
    assert packed.shape == (3, 3, -(-width // 8))
    back = exchange.unpack_bits(packed, width)
    assert np.array_equal(np.asarray(back), np.asarray(flag))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16), st.integers(2, 5), st.integers(1, 17),
       st.integers(1, 4))
def test_compact_wire_bitwise_roundtrip(seed, S, H, d):
    """encode -> decode through the compact wire is the identity on
    values and flags, including ragged per-pair occupied widths."""
    buf_m, buf_c, flag = _rand_halo(seed, S=S, H=H, d=d)
    wire = exchange.get_wire("compact")
    payload, _, _ = wire.encode(buf_m, buf_c, flag)
    out_m, out_c, out_f = wire.decode(payload)
    assert np.array_equal(np.asarray(out_m), np.asarray(buf_m))
    assert np.array_equal(np.asarray(out_c), np.asarray(buf_c))
    assert np.array_equal(np.asarray(out_f), np.asarray(flag))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**16))
def test_int8_roundtrip_error_bound(seed):
    """|dequantize(q) - (x + err)| <= scale/2 per component — the exact
    bound the audit tolerance's ``quant_eps = 1/254`` encodes."""
    buf_m, buf_c, flag = _rand_halo(seed)
    rng = np.random.default_rng(seed + 1)
    err_m = jnp.asarray(rng.normal(size=buf_m.shape).astype(np.float32))
    err_c = jnp.asarray(rng.normal(size=buf_c.shape).astype(np.float32))
    pack, _, _ = quantize_halo(buf_m, buf_c, flag, err_m, err_c)
    fm = np.asarray(flag)[..., None]
    xm = np.where(fm, np.asarray(buf_m) + np.asarray(err_m), 0.0)
    xc = np.where(np.asarray(flag), np.asarray(buf_c) + np.asarray(err_c),
                  0.0)
    deq_m = np.asarray(pack.q_m, np.float32) * \
        np.asarray(pack.scale_m)[..., None, None]
    deq_c = np.asarray(pack.q_c, np.float32) * \
        np.asarray(pack.scale_c)[..., None]
    half_m = np.asarray(pack.scale_m)[..., None, None] / 2 + 1e-7
    half_c = np.asarray(pack.scale_c)[..., None] / 2 + 1e-7
    assert (np.abs(deq_m - xm) <= half_m).all()
    assert (np.abs(deq_c - xc) <= half_c).all()
    # relative form: scale/2 == max|x| / 254 == quant_eps * max|x|
    wire = exchange.get_wire("int8")
    mx = np.abs(xm).max(axis=(-2, -1))
    assert (np.abs(deq_m - xm).max(axis=(-2, -1))
            <= wire.quant_eps * mx + 1e-6).all()


def test_bf16_error_bound():
    """Flagged (actually delivered) components obey the 2^-8 relative
    bound; unflagged entries are never scattered, so they are exempt."""
    buf_m, buf_c, flag = _rand_halo(7)
    wire = exchange.get_wire("bf16")
    payload, _, _ = wire.encode(buf_m, buf_c, flag)
    out_m, out_c, out_f = wire.decode(payload)
    fm = np.broadcast_to(np.asarray(flag)[..., None], buf_m.shape)
    xm = np.asarray(buf_m)[fm]
    assert (np.abs(np.asarray(out_m)[fm] - xm)
            <= wire.quant_eps * np.abs(xm) + 1e-7).all()
    xc = np.asarray(buf_c)[np.asarray(flag)]
    assert (np.abs(np.asarray(out_c)[np.asarray(flag)] - xc)
            <= wire.quant_eps * np.abs(xc) + 1e-7).all()
    assert np.array_equal(np.asarray(out_f), np.asarray(flag))


def test_wire_registry():
    assert set(exchange.WIRE_FORMATS) == {"exact", "compact", "int8", "bf16"}
    try:
        exchange.get_wire("zstd")
        assert False, "unknown wire must raise"
    except ValueError as e:
        assert "zstd" in str(e)


# ---------------------------------------------------------------------------
# byte model: compact/quantized must undercut exact
# ---------------------------------------------------------------------------


def test_pair_bytes_ordering_and_padding():
    counts = np.array([[0, 5, 0], [3, 0, 9], [0, 0, 0]])
    width, d = 16, 2
    exact = exchange.get_wire("exact").pair_bytes(counts, width, d)
    compact = exchange.get_wire("compact").pair_bytes(counts, width, d)
    int8 = exchange.get_wire("int8").pair_bytes(counts, width, d)
    assert (np.diag(exact) == 0).all()
    # exact ships the dense width even on silent pairs; compact ships
    # occupied slots only (silent pairs: nothing).
    assert exact[0, 2] > 0 and compact[0, 2] == 0 and int8[0, 2] == 0
    active = counts > 0
    assert (compact[active] < exact[active]).all()
    assert (int8[active] < compact[active]).all()


# ---------------------------------------------------------------------------
# engine parity: compact is bitwise-invisible on every path
# ---------------------------------------------------------------------------


def _engine_pair(topo, wire, seed=0, drop=0.0, **ecfg_kw):
    spec = sim.ProblemSpec(n=topo.n, seed=seed)
    centers, sample, _, _ = sim.make_problem(spec)
    rng = np.random.default_rng(seed + 1)
    inputs = wvs.from_vector(jnp.asarray(sample(rng, topo.n)),
                             jnp.ones((topo.n,), jnp.float32))
    cfg = lss.LSSConfig(drop_rate=drop)
    eng = ShardedLSS(topo, centers, cfg,
                     EngineConfig(num_shards=4, cycles_per_dispatch=4,
                                  halo_slack=1.5, wire=wire, **ecfg_kw))
    return eng, eng.init(inputs, seed=seed)


def _assert_states_bitwise(a, b):
    for f in a._fields:
        x, y = getattr(a, f), getattr(b, f)
        if x is None and y is None:
            continue
        assert np.array_equal(np.asarray(x), np.asarray(y)), f


def test_compact_engine_bitwise_parity_with_drops():
    """Sync gather path, message drops on: every state field (drop-RNG
    stream included) identical between exact and compact."""
    topo = topology.grid(100)
    e0, s0 = _engine_pair(topo, "exact", drop=0.15)
    e1, s1 = _engine_pair(topo, "compact", drop=0.15)
    assert e1._wire_w < e0.stopo.halo_width  # the trim actually engaged
    s0, s1 = e0.run(s0, 24), e1.run(s1, 24)
    _assert_states_bitwise(s0, s1)


def test_compact_async_bitwise_parity():
    """Bounded-staleness ring path: compact stays bitwise (it is value-
    lossless; only the byte accounting changes)."""
    topo = topology.grid(100)
    e0, s0 = _engine_pair(topo, "exact", drop=0.1,
                          async_mode=True, staleness=2)
    e1, s1 = _engine_pair(topo, "compact", drop=0.1,
                          async_mode=True, staleness=2)
    s0, s1 = e0.run(s0, 24), e1.run(s1, 24)
    _assert_states_bitwise(s0.sync, s1.sync)
    assert np.array_equal(np.asarray(s0.last_seq), np.asarray(s1.last_seq))
    assert int(jnp.sum(s0.applied)) == int(jnp.sum(s1.applied))


def test_compact_mesh_bitwise_parity(subproc):
    """shard_map + collective_all_to_all transport, 4 real devices."""
    out = subproc("""
import jax, numpy as np, jax.numpy as jnp
from repro.core import lss, sim, topology, wvs
from repro.engine import ShardedLSS, EngineConfig

topo = topology.grid(64)
spec = sim.ProblemSpec(n=64, seed=0)
centers, sample, _, _ = sim.make_problem(spec)
rng = np.random.default_rng(1)
inputs = wvs.from_vector(jnp.asarray(sample(rng, topo.n)),
                         jnp.ones((topo.n,), jnp.float32))
mesh = jax.make_mesh((4,), ("shards",))
states = {}
for wire in ("exact", "compact"):
    eng = ShardedLSS(topo, centers, lss.LSSConfig(drop_rate=0.1),
                     EngineConfig(num_shards=4, cycles_per_dispatch=4,
                                  halo_slack=1.5, wire=wire)
                     ).use_mesh(mesh, "shards")
    states[wire] = eng.run(eng.init(inputs, seed=0), 24)
a, b = states["exact"], states["compact"]
for f in a._fields:
    x, y = getattr(a, f), getattr(b, f)
    if x is None and y is None:
        continue
    assert np.array_equal(np.asarray(x), np.asarray(y)), f
print("MESH_COMPACT_PARITY_OK")
""", n_devices=4)
    assert "MESH_COMPACT_PARITY_OK" in out


def test_service_engine_backend_compact_parity():
    """The service's engine backend (sync and overlap) is bitwise
    unchanged under engine_wire='compact' — records included."""
    from repro.core import regions
    from repro.obs import InMemoryTracker
    from repro.service import QuerySpec, Service, ServiceConfig

    topo = topology.grid(36)
    spec = sim.ProblemSpec(n=36, seed=5)
    centers, sample, _, _ = sim.make_problem(spec)
    x = sample(np.random.default_rng(6), topo.n)

    def run(wire, overlap):
        tr = InMemoryTracker()
        svc = Service(topo, ServiceConfig(
            capacity=2, k_max=3, d=2, cycles_per_dispatch=5,
            backend="engine", engine_shards=2, engine_wire=wire,
            overlap=overlap), tracker=tr)
        qid = svc.admit(QuerySpec(region=regions.VoronoiRegions(centers),
                                  inputs=x, seed=0))
        svc.serve(4)
        snap = svc.snapshot(qid)
        recs = [r for r in tr.records if "query" in r]
        svc.close()
        return snap, recs

    for overlap in (False, True):
        s0, r0 = run("exact", overlap)
        s1, r1 = run("compact", overlap)
        for f in s0._fields:
            assert np.array_equal(np.asarray(getattr(s0, f)),
                                  np.asarray(getattr(s1, f))), (overlap, f)
        assert r0 == r1, overlap


# ---------------------------------------------------------------------------
# quantized wire: convergence, composition with loss/staleness/migration
# ---------------------------------------------------------------------------


def test_int8_convergence_static_workloads():
    """fig3-style workloads: int8 transport reaches the same decisions
    (final accuracy / quiescence) as the exact engine."""
    for make in (lambda: topology.grid(100),
                 lambda: topology.barabasi_albert(100, m=2, seed=0)):
        topo = make()
        spec = sim.ProblemSpec(n=topo.n, seed=3)
        r_exact = sim.run_static(topo, spec, max_cycles=400,
                                 engine=EngineConfig(num_shards=4,
                                                     cycles_per_dispatch=4))
        r_int8 = sim.run_static(topo, spec, max_cycles=400,
                                engine=EngineConfig(num_shards=4,
                                                    cycles_per_dispatch=4,
                                                    wire="int8"))
        assert r_int8["final_accuracy"] == r_exact["final_accuracy"] == 1.0
        assert r_int8["quiescent"]


def test_int8_convergence_under_message_loss():
    """fig4-style: quantization composes with message drops (the paper's
    perturbation-robustness argument covers both at once)."""
    topo = topology.grid(100)
    spec = sim.ProblemSpec(n=topo.n, seed=4)
    r = sim.run_static(topo, spec, cfg=lss.LSSConfig(drop_rate=0.2),
                       max_cycles=600,
                       engine=EngineConfig(num_shards=4,
                                           cycles_per_dispatch=4,
                                           wire="int8"))
    assert r["final_accuracy"] == 1.0


def test_int8_with_async_staleness():
    """Error feedback updates at the sender's publish boundary, so it
    survives bounded-staleness delivery."""
    topo = topology.grid(100)
    e, s = _engine_pair(topo, "int8", seed=2, drop=0.1,
                        async_mode=True, staleness=2)
    s = e.run(s, 120)
    acc, _, _ = e.metrics(s)
    assert float(acc) == 1.0
    assert s.sync.wire_err_m is not None
    a = e.audit(s)
    assert a["resid"] <= a["tol"] and a["seq_bad"] == 0


def test_int8_audit_stays_green():
    """audit_every-style check: conservation residual within the widened
    rounding model and edge symmetry relaxed to intra slots only."""
    topo = topology.grid(100)
    e, s = _engine_pair(topo, "int8", seed=1)
    s = e.run(s, 40)
    a = e.audit(s)
    assert a["resid"] <= a["tol"], a
    assert a["edge_bad"] == 0, a  # intra slots stay bitwise-symmetric
    assert a["edge_checked"] > 0
    # the relaxation is bounded: halo slots were excluded, not everything
    e0, s0 = _engine_pair(topo, "exact", seed=1)
    a0 = e0.audit(e0.run(s0, 40))
    assert a["edge_checked"] < a0["edge_checked"]


def test_int8_error_feedback_survives_migration():
    """migrate_from carries per-slot quantization debt row-for-row into
    the new layout; the run continues and converges."""
    topo = topology.grid(100)
    e1, s = _engine_pair(topo, "int8", seed=0)
    s = e1.run(s, 12)
    assert float(jnp.abs(s.wire_err_m).max()) > 0  # debt actually accrued
    spec = sim.ProblemSpec(n=topo.n, seed=0)
    centers, _, _, _ = sim.make_problem(spec)
    e2 = ShardedLSS(topo, centers, lss.LSSConfig(),
                    EngineConfig(num_shards=4, cycles_per_dispatch=4,
                                 halo_slack=1.5, wire="int8",
                                 method="stride"))
    s2 = e2.migrate_from(e1, s)
    # row-for-row: old row r's error slots land at the new layout's
    # position of the same logical peer
    old_flat = np.asarray(s.wire_err_m).reshape(e1.S * e1.B, e1.D, -1)
    new_flat = np.asarray(s2.wire_err_m).reshape(e2.S * e2.B, e2.D, -1)
    old_pos = np.asarray(e1._pos)
    new_pos = np.asarray(e2._pos)
    assert np.array_equal(new_flat[new_pos], old_flat[old_pos])
    s2 = e2.run(s2, 100)
    acc, _, _ = e2.metrics(s2)
    assert float(acc) == 1.0


# ---------------------------------------------------------------------------
# byte accounting + autotuner
# ---------------------------------------------------------------------------


def test_halo_bytes_span_attr_reports_wire_bytes():
    from repro.obs import InMemoryTracker

    topo = topology.grid(100)
    vals = {}
    for wire in ("exact", "compact", "int8"):
        spec = sim.ProblemSpec(n=topo.n, seed=0)
        centers, sample, _, _ = sim.make_problem(spec)
        inputs = wvs.from_vector(
            jnp.asarray(sample(np.random.default_rng(1), topo.n)),
            jnp.ones((topo.n,), jnp.float32))
        tr = InMemoryTracker()
        eng = ShardedLSS(topo, centers, lss.LSSConfig(),
                         EngineConfig(num_shards=4, cycles_per_dispatch=4,
                                      halo_slack=1.5, wire=wire),
                         tracker=tr)
        eng.run(eng.init(inputs, seed=0), 4)
        spans = tr.spans_named("engine.dispatch")
        assert spans and spans[0].attrs["wire"] == wire
        vals[wire] = spans[0].attrs["halo_bytes"]
        # per-shard counters sum to the span totals
        c = tr.registry.get("engine_shard_halo_bytes_total")
        assert sum(v for _, v in c.series()) == \
            sum(s.attrs["halo_bytes"] for s in spans)
        assert vals[wire] == 4 * int(eng.wire_pair_bytes(2).sum())
        pad = tr.registry.get("engine_halo_padding_frac")
        assert pad is not None  # per-pair padding visibility
        assert all(0.0 <= v <= 1.0 for _, v in pad.series())
    assert vals["compact"] < vals["exact"]
    assert vals["int8"] < vals["compact"]


def test_autotune_plan_table_and_acceptance():
    """The adopted plan's measured dispatch wall is within 10% of the
    best enumerated candidate (ISSUE 10 acceptance)."""
    topo = topology.grid(400)
    centers = jax.random.normal(jax.random.PRNGKey(0), (3, 2))
    cands = [autotune.Candidate(2, 1.5, k, w)
             for k in (2, 8) for w in ("exact", "compact")]
    res = autotune.plan(topo, centers, candidates=cands, repeats=2)
    assert len(res.table) == 4
    best = min(e.measured_us for e in res.table)
    chosen = next(e for e in res.table if e.cand == res.chosen)
    assert chosen.measured_us <= 1.10 * best
    assert res.config.auto_plan is False
    by_wire = {(e.cand.k, e.cand.wire): e for e in res.table}
    assert by_wire[(8, "compact")].wire_bytes < \
        by_wire[(8, "exact")].wire_bytes
    # the model ranks compact at or below exact for equal K
    assert by_wire[(8, "compact")].modeled_us <= \
        by_wire[(8, "exact")].modeled_us
    assert "chosen" in autotune.format_table(res)


def test_auto_plan_constructs_and_runs():
    topo = topology.grid(100)
    centers = jax.random.normal(jax.random.PRNGKey(0), (3, 2))
    eng = ShardedLSS(topo, centers, lss.LSSConfig(),
                     EngineConfig(num_shards=2, cycles_per_dispatch=4,
                                  auto_plan=True))
    assert eng.ecfg.auto_plan is False  # plan adopted, no re-planning
    x = jax.random.normal(jax.random.PRNGKey(1), (topo.n, 2))
    st = eng.run(eng.init(wvs.WV(m=x, c=jnp.ones((topo.n,)))), 8)
    assert int(st.t) == 8
