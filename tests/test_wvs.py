"""Property tests for the weighted vector space (Def. 1), moment form."""

import jax.numpy as jnp
import numpy as np

try:  # real hypothesis when installed (CI); seeded fallback shim otherwise
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import wvs

finite = st.floats(min_value=-100, max_value=100, allow_nan=False,
                   allow_infinity=False)
pos = st.floats(min_value=0.0078125, max_value=128.0, allow_nan=False)


def wv_strategy(d=3):
    return st.tuples(
        st.lists(finite, min_size=d, max_size=d),
        pos,
    ).map(lambda t: wvs.from_vector(jnp.array(t[0], jnp.float32),
                                    jnp.float32(t[1])))


@settings(max_examples=50, deadline=None)
@given(wv_strategy(), wv_strategy())
def test_add_commutative(x, y):
    assert wvs.allclose(wvs.add(x, y), wvs.add(y, x))


@settings(max_examples=50, deadline=None)
@given(wv_strategy(), wv_strategy(), wv_strategy())
def test_add_associative(x, y, z):
    a = wvs.add(wvs.add(x, y), z)
    b = wvs.add(x, wvs.add(y, z))
    assert np.allclose(a.m, b.m, rtol=1e-4, atol=1e-4)
    assert np.allclose(a.c, b.c, rtol=1e-5)


@settings(max_examples=50, deadline=None)
@given(wv_strategy())
def test_identity_element(x):
    z = wvs.zero(x.d)
    assert wvs.allclose(wvs.add(x, z), x)


@settings(max_examples=50, deadline=None)
@given(wv_strategy(), wv_strategy())
def test_sub_inverts_add(x, y):
    # X = Y (+) Z  =>  Z = X (-) Y  (footnote 1: defined since weights > 0)
    z = wvs.sub(wvs.add(x, y), y)
    # f32 cancellation scales with the larger moment magnitude
    scale = max(1.0, float(np.max(np.abs(np.asarray(y.m)))))
    assert np.allclose(z.m, x.m, atol=1e-3 * scale, rtol=1e-4)
    assert np.allclose(z.c, x.c, rtol=1e-4, atol=1e-5)


@settings(max_examples=50, deadline=None)
@given(wv_strategy(), st.floats(min_value=0.125, max_value=8.0))
def test_smul_scales_weight_not_vector(x, s):
    y = wvs.smul(jnp.float32(s), x)
    # vector part unchanged (paper: c (.) <v, c2> = <v, c*c2>)
    assert np.allclose(wvs.vec(y), wvs.vec(x), rtol=1e-4, atol=1e-5)
    assert np.allclose(y.c, s * x.c, rtol=1e-5)


@settings(max_examples=50, deadline=None)
@given(wv_strategy(), wv_strategy())
def test_weighted_average_definition(x, y):
    """(+) is the weighted average of the vector parts (Def. 1)."""
    z = wvs.add(x, y)
    want = (x.c * wvs.vec(x) + y.c * wvs.vec(y)) / (x.c + y.c)
    assert np.allclose(wvs.vec(z), want, rtol=1e-4, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.lists(wv_strategy(), min_size=2, max_size=6))
def test_wsum_matches_fold(xs):
    batched = wvs.WV(jnp.stack([x.m for x in xs]), jnp.stack([x.c for x in xs]))
    total = wvs.wsum(batched, axis=0)
    acc = xs[0]
    for x in xs[1:]:
        acc = wvs.add(acc, x)
    assert np.allclose(total.m, acc.m, rtol=1e-4, atol=1e-4)
    assert np.allclose(total.c, acc.c, rtol=1e-5)


def test_triangle_inequality_vector_part():
    # ||vec(X (+) Y)|| <= max component norm: convex combination property.
    rng = np.random.default_rng(0)
    for _ in range(20):
        x = wvs.from_vector(jnp.array(rng.normal(size=3), jnp.float32),
                            jnp.float32(rng.uniform(0.1, 5)))
        y = wvs.from_vector(jnp.array(rng.normal(size=3), jnp.float32),
                            jnp.float32(rng.uniform(0.1, 5)))
        z = wvs.add(x, y)
        n = float(jnp.linalg.norm(wvs.vec(z)))
        assert n <= max(float(jnp.linalg.norm(wvs.vec(x))),
                        float(jnp.linalg.norm(wvs.vec(y)))) + 1e-5
